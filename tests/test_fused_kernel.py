"""Fused frontier relax+reduce kernel vs oracles, and the engine hot path.

Covers the ISSUE-1 acceptance matrix: kernel parity vs the jnp oracle
across semirings / frontier densities / padding / non-block-multiple
shapes, engine equivalence (use_pallas=True vs the jnp path) for
BFS/SSSP/PageRank under dense and compact exchange in run_stacked and
run_sharded, and the frontier chunk-skip actually firing on late sparse
rounds.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.apps import bfs, sssp, pagerank
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.kernels.fused_relax_reduce import (
    EBLK, SBLK, fused_grid_cells, fused_relax_reduce_pallas,
)
from repro.kernels.ref import fused_relax_reduce_ref


def _case(v, e, nseg, frontier_frac, seed, sorted_ids=True):
    rng = np.random.default_rng(seed)
    gval = rng.uniform(0.0, 10.0, v).astype(np.float32)
    gchg = rng.random(v) < frontier_frac
    src = rng.integers(0, v, e).astype(np.int32)
    w = rng.uniform(0.1, 2.0, e).astype(np.float32)
    mask = rng.random(e) < 0.9
    ids = rng.integers(0, nseg, e).astype(np.int32)
    if sorted_ids:
        ids = np.sort(ids)
    return tuple(jnp.asarray(x) for x in (gval, gchg, src, w, mask, ids))


SHAPES = [
    (1, 1, 1), (17, 7, 3), (200, 100, 17),
    (300, EBLK, SBLK), (130, EBLK + 1, SBLK + 1),
    (500, 2 * EBLK + 13, 2 * SBLK + 5), (64, EBLK - 1, 1000),
]


@pytest.mark.parametrize("relax,kind", [
    ("add_w", "min"), ("add_one", "min"), ("mul_w", "sum")])
@pytest.mark.parametrize("v,e,nseg", SHAPES)
def test_fused_matches_ref(relax, kind, v, e, nseg):
    gval, gchg, src, w, mask, ids = _case(v, e, nseg, 0.4, seed=e + nseg)
    got = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids, nseg,
                                    relax, kind, interpret=True)
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, nseg,
                                  relax, kind)
    if kind == "min":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("relax,kind", [("add_w", "min"), ("mul_w", "sum")])
@pytest.mark.parametrize("frontier_frac", [0.0, 0.05, 1.0])
def test_fused_frontier_densities(relax, kind, frontier_frac):
    """Empty, sparse, and full frontiers all reduce correctly — the chunk
    skip must never drop a live contribution."""
    gval, gchg, src, w, mask, ids = _case(400, 3 * EBLK + 9, 700,
                                          frontier_frac, seed=5)
    got = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids, 700,
                                    relax, kind, interpret=True)
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, 700,
                                  relax, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    if frontier_frac == 0.0:
        identity = np.inf if kind == "min" else 0.0
        assert np.all(np.asarray(got) == identity)


@pytest.mark.parametrize("kind", ["min", "sum"])
def test_fused_padding_edges_inert(kind):
    """Masked-off (padding) edges never contribute, whatever their ids."""
    relax = "add_w" if kind == "min" else "mul_w"
    gval = jnp.asarray(np.arange(10, dtype=np.float32))
    gchg = jnp.ones(10, bool)
    src = jnp.asarray([0, 1, 2, 3], jnp.int32)
    w = jnp.ones(4, jnp.float32)
    mask = jnp.asarray([True, True, False, False])
    ids = jnp.asarray([2, 2, 0, 5], jnp.int32)   # padding aimed at live segs
    got = np.asarray(fused_relax_reduce_pallas(
        gval, gchg, src, w, mask, ids, 6, relax, kind, interpret=True))
    identity = np.inf if kind == "min" else 0.0
    expect0 = identity          # only padding pointed at segment 0
    expect5 = identity
    assert got[0] == expect0 and got[5] == expect5
    if kind == "min":
        assert got[2] == 1.0    # min(0+1, 1+1)
    else:
        assert got[2] == 1.0    # 0*1 + 1*1


def test_fused_rejects_non_absorbing_pairing():
    """Frontier masking relies on relax(identity, w) == identity; pairings
    without that property must be rejected, not silently mis-summed."""
    gval, gchg, src, w, mask, ids = _case(50, 100, 40, 0.5, seed=3)
    with pytest.raises(ValueError, match="non-absorbing"):
        fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids, 40,
                                  "add_w", "sum", interpret=True)


def test_fixpoint_runners_reject_sum_semirings():
    """run_stacked/make_sharded_fn collapse combined candidates — only
    sound for min semirings; sum semirings must be routed to the PageRank
    runners instead of silently double-counting sibling values."""
    g = generators.ring(32)
    from repro.core.partition import PartitionConfig, build_partition
    part = build_partition(g, PartitionConfig(num_shards=2))
    init = engine.init_values(part, actions.PAGERANK, {})
    with pytest.raises(ValueError, match="min-semiring"):
        engine.run_stacked(actions.PAGERANK, part, init)
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="min-semiring"):
        engine.make_sharded_fn(actions.PAGERANK, part.S, part.R_max, mesh)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_track_stats_off_is_consistent(use_pallas):
    """track_stats=False zeroes the message/pruned counters identically on
    the fused and jnp paths (values unaffected)."""
    g = generators.erdos_renyi(150, avg_degree=4.0, seed=2)
    root = int(g.src[0])
    on, s_on, _ = bfs(g, root, num_shards=4,
                      cfg=engine.EngineConfig(use_pallas=use_pallas))
    off, s_off, _ = bfs(g, root, num_shards=4,
                        cfg=engine.EngineConfig(use_pallas=use_pallas,
                                                track_stats=False))
    np.testing.assert_array_equal(off, on)
    assert int(s_on.messages) > 0
    assert int(s_off.messages) == 0 and int(s_off.pruned_actions) == 0
    assert int(s_off.iterations) == int(s_on.iterations)


def test_fused_unsorted_ids_still_correct():
    """The range skip is an optimization over sorted dsts; correctness must
    hold for arbitrary id order."""
    gval, gchg, src, w, mask, ids = _case(300, 1000, 400, 0.5, seed=11,
                                          sorted_ids=False)
    got = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids, 400,
                                    "add_w", "min", interpret=True)
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, 400,
                                  "add_w", "min")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# engine equivalence: use_pallas=True vs the jnp oracle path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("exchange", ["dense", "compact"])
def test_engine_stacked_pallas_matches_jnp(exchange):
    g = generators.ba_skewed(260, m_per=4, seed=9).with_random_weights(seed=9)
    root = int(np.argmax(g.out_degrees()))
    cfg_j = engine.EngineConfig(exchange=exchange)
    cfg_p = engine.EngineConfig(exchange=exchange, use_pallas=True)

    lv_j, st_j, _ = bfs(g, root, num_shards=8, rpvo_max=4, cfg=cfg_j)
    lv_p, st_p, _ = bfs(g, root, num_shards=8, rpvo_max=4, cfg=cfg_p)
    np.testing.assert_array_equal(lv_j, reference.bfs_levels(g, root))
    np.testing.assert_array_equal(lv_p, lv_j)          # bit-identical (min)
    assert int(st_p.messages) == int(st_j.messages)
    assert int(st_p.pruned_actions) == int(st_j.pruned_actions)

    d_j, _, _ = sssp(g, root, num_shards=8, rpvo_max=4, cfg=cfg_j)
    d_p, _, _ = sssp(g, root, num_shards=8, rpvo_max=4, cfg=cfg_p)
    np.testing.assert_array_equal(d_p, d_j)            # bit-identical (min)

    pr_j, _ = pagerank(g, iters=15, num_shards=8, rpvo_max=4, cfg=cfg_j)
    pr_p, _ = pagerank(g, iters=15, num_shards=8, rpvo_max=4, cfg=cfg_p)
    np.testing.assert_allclose(pr_j, reference.pagerank(g, iters=15),
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(pr_p, pr_j, rtol=1e-5, atol=1e-9)


def test_engine_compact_sum_semiring_matches_dense():
    """The compact targeted exchange now carries the sum semiring: compact
    PageRank must agree with the dense path and the numpy oracle."""
    g = generators.rmat(8, edge_factor=6, seed=3)
    pr_dense, _ = pagerank(g, iters=20, num_shards=8, rpvo_max=4,
                           cfg=engine.EngineConfig(exchange="dense"))
    pr_comp, _ = pagerank(g, iters=20, num_shards=8, rpvo_max=4,
                          cfg=engine.EngineConfig(exchange="compact"))
    np.testing.assert_allclose(pr_comp, reference.pagerank(g, iters=20),
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(pr_comp, pr_dense, rtol=1e-5, atol=1e-9)


def test_engine_stacked_vs_sharded_pallas():
    """use_pallas=True on the trivial 1-device mesh == stacked fused run."""
    from jax.sharding import Mesh
    g = generators.erdos_renyi(180, avg_degree=4.0, seed=21)
    root = int(g.src[0])
    cfg = engine.EngineConfig(use_pallas=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    lv_st, _, _ = bfs(g, root, num_shards=1, cfg=cfg)
    lv_sh, _, _ = bfs(g, root, num_shards=1, mesh=mesh, cfg=cfg)
    np.testing.assert_array_equal(lv_sh, lv_st)
    np.testing.assert_array_equal(lv_st, reference.bfs_levels(g, root))


# --------------------------------------------------------------------------
# frontier chunk-skip: late sparse rounds execute fewer grid cells
# --------------------------------------------------------------------------

def test_frontier_skip_fires_on_late_rounds():
    """Drive BFS round-by-round on a long path (ring): the frontier is one
    vertex per round, so the fused kernel must skip grid cells that the
    range-skip alone (the unfused reduce kernel) would execute. The ring is
    sized to several EBLK edge chunks so dead chunks exist to skip."""
    g = generators.ring(4 * EBLK)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=1))
    sem = actions.BFS
    arrays = engine.DeviceArrays.from_partition(part)
    init = engine.init_values(part, sem, {0: 0.0})
    val_p = val_j = jnp.asarray(init)
    chg0 = sem.improved(val_p, jnp.full_like(val_p, sem.identity)) \
        & arrays.slot_valid
    chg_p = chg_j = chg0
    cfg_p = engine.EngineConfig(use_pallas=True)
    cfg_j = engine.EngineConfig(use_pallas=False)
    total = part.S * part.R_max

    rounds = []
    for _ in range(10):
        cells = fused_grid_cells(part.edge_dst_flat, part.edge_mask,
                                 part.edge_src_root_flat,
                                 np.asarray(chg_p).reshape(-1), total)
        rounds.append(cells)
        val_p, chg_p, _ = engine._fixpoint_round_stacked(
            sem, arrays, cfg_p, part.S, part.R_max, val_p, chg_p)
        val_j, chg_j, _ = engine._fixpoint_round_stacked(
            sem, arrays, cfg_j, part.S, part.R_max, val_j, chg_j)
        # the skip is exact, never lossy: fused == oracle every round
        np.testing.assert_array_equal(np.asarray(val_p), np.asarray(val_j))
        np.testing.assert_array_equal(np.asarray(chg_p), np.asarray(chg_j))
    late = rounds[-1]
    assert late["fused_live"] < late["range_live"], rounds
    assert all(r["fused_live"] <= r["range_live"] for r in rounds)


@pytest.mark.parametrize("relax,kind", [
    ("add_w", "min"), ("add_one", "min"), ("mul_w", "sum")])
@pytest.mark.parametrize("v,e,nseg", SHAPES)
def test_grid_cell_dma_oracle_matches_kernel(relax, kind, v, e, nseg):
    """ISSUE-4 satellite: the host-side ``fused_grid_cells`` mirror
    (extended with per-cell tile counts) must EXACTLY match the
    kernel-side executed-cell / issued-DMA counters (``with_debug``) on
    every kernel-parity case — pinned (cells only; the table rides in
    via BlockSpec, no manual DMA) and tiled (cells + per-cell tile
    fetches) alike.  Previously the mirror was only spot-checked in
    benchmarks."""
    gval, gchg, src, w, mask, ids = _case(v, e, nseg, 0.4, seed=e + nseg)
    vblk = 128
    mirror = fused_grid_cells(np.asarray(ids), np.asarray(mask),
                              np.asarray(src), np.asarray(gchg), nseg,
                              vblk=vblk)
    _, pin_dbg = fused_relax_reduce_pallas(
        gval, gchg, src, w, mask, ids, nseg, relax, kind,
        path="pinned", with_debug=True)
    assert int(pin_dbg[0]) == mirror["fused_live"]
    assert int(pin_dbg[1]) == 0
    _, til_dbg = fused_relax_reduce_pallas(
        gval, gchg, src, w, mask, ids, nseg, relax, kind,
        path="tiled", vblk=vblk, with_debug=True)
    assert int(til_dbg[0]) == mirror["fused_live"]
    assert int(til_dbg[1]) == mirror["fused_tile_dmas"]
    assert mirror["dma_bytes"] == mirror["fused_tile_dmas"] * vblk * 4


@pytest.mark.parametrize("frontier_frac", [0.0, 0.05, 1.0])
def test_grid_cell_dma_oracle_matches_kernel_lanes(frontier_frac):
    """Laned launch oracle: the mirror over the OR-across-lanes frontier
    matches the laned kernels' executed-cell / issued-DMA counters."""
    from repro.kernels.fused_relax_reduce import (
        fused_relax_reduce_lanes_pallas,
    )
    rng = np.random.default_rng(3)
    v, e, nseg, q = 300, 2 * EBLK + 7, 500, 3
    gval = jnp.asarray(rng.uniform(0, 10, (v, q)).astype(np.float32))
    gchg = jnp.asarray(rng.random((v, q)) < frontier_frac)
    unitw = jnp.asarray([1, 0, 1], jnp.int32)
    src = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    w = jnp.asarray(rng.uniform(0.1, 2, e).astype(np.float32))
    mask = jnp.asarray(rng.random(e) < 0.9)
    ids = jnp.asarray(np.sort(rng.integers(0, nseg, e)).astype(np.int32))
    vblk = 128
    from repro.kernels.fused_relax_reduce import _lane_pad
    mirror = fused_grid_cells(np.asarray(ids), np.asarray(mask),
                              np.asarray(src),
                              np.asarray(gchg).any(axis=-1), nseg,
                              vblk=vblk,
                              lane_width=_lane_pad(q, interpret=True))
    for path in ("pinned", "tiled"):
        _, dbg = fused_relax_reduce_lanes_pallas(
            gval, gchg, unitw, src, w, mask, ids, nseg, "add_w", "min",
            path=path, vblk=vblk if path == "tiled" else None,
            with_debug=True)
        assert int(dbg[0]) == mirror["fused_live"]
        assert int(dbg[1]) == (mirror["fused_tile_dmas"]
                               if path == "tiled" else 0)


def test_grid_cell_counter_matches_kernel_semantics():
    """fused_grid_cells mirrors the launch predicates: a dead frontier
    yields zero live fused cells; a full frontier can never beat the
    unfused range-skip count by more than the mask-aware ranges allow."""
    gval, gchg, src, w, mask, ids = _case(300, 2000, 500, 1.0, seed=2)
    full = fused_grid_cells(ids, mask, src, np.ones(300, bool), 500)
    dead = fused_grid_cells(ids, mask, src, np.zeros(300, bool), 500)
    assert 0 < full["fused_live"] <= full["range_live"]
    assert dead["fused_live"] == 0
    assert dead["range_live"] == full["range_live"]   # no frontier skip there
    assert full["total_fused"] >= full["fused_live"]
    assert full["total_unfused"] >= full["range_live"]
