"""Sharding rules: divisibility fallback, role binding, elasticity."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import RULESETS, ShardCtx, spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _ctx(shape, rules="default"):
    dp = tuple(a for a in ("pod", "data") if a in shape)
    return ShardCtx(mesh=FakeMesh(shape), rules=rules, dp=dp, tp=("model",))


def test_basic_tp_dp_mapping():
    ctx = _ctx({"data": 16, "model": 16})
    spec = spec_for(("embed", "mlp"), ctx, (4096, 16384))
    assert spec == P("data", "model")


def test_non_dividing_dim_falls_back_to_replicated():
    ctx = _ctx({"data": 16, "model": 16})
    # 8 kv heads on a 16-way model axis: must drop, not crash
    spec = spec_for(("embed", "kv_heads", None), ctx, (4096, 8, 128))
    assert spec == P("data", None, None)


def test_axis_used_once():
    ctx = _ctx({"data": 16, "model": 16})
    # two logical dims both mapping to tp: only the first gets it
    spec = spec_for(("heads", "mlp"), ctx, (64, 25600))
    assert spec == P("model", None)


def test_multipod_dp_spans_pod_and_data():
    ctx = _ctx({"pod": 2, "data": 16, "model": 16})
    spec = spec_for(("act_batch", None), ctx, (256, 4096))
    assert spec == P(("pod", "data"), None)


def test_elastic_relowering_same_rules_any_mesh():
    """The same logical axes produce valid specs at any mesh size — the
    elastic re-mesh path never edits model code."""
    for shape in ({"data": 2, "model": 2}, {"data": 8, "model": 4},
                  {"pod": 2, "data": 4, "model": 8}):
        ctx = _ctx(shape)
        spec = spec_for(("embed", "heads", None), ctx, (1024, 64, 128))
        assert len(spec) == 3


def test_opt_rules_shard_kv_seq():
    ctx = _ctx({"data": 16, "model": 16}, rules="opt")
    spec = spec_for(("layers", "act_batch", "act_kv_seq", "act_kv_heads",
                     None), ctx, (40, 128, 32768, 8, 128))
    assert spec[2] == "model"    # sequence dim takes tp
    assert spec[3] is None       # kv heads yield (axis already used)
