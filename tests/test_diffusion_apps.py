"""Diffusive BFS/SSSP/PageRank vs numpy oracles, across partition modes."""
import numpy as np
import pytest

from repro.apps import bfs, sssp, pagerank
from repro.core.partition import PartitionConfig, build_partition
from repro.core import engine, actions
from repro.graph import generators, reference
from repro.graph.graph import COOGraph


GRAPHS = {
    "ring": lambda: generators.ring(64),
    "star_in": lambda: generators.star(100, hub=7, inward=True),
    "star_out": lambda: generators.star(100, hub=7, inward=False),
    "er": lambda: generators.erdos_renyi(300, avg_degree=5.0, seed=1),
    "rmat": lambda: generators.rmat(9, edge_factor=8, seed=2),
    "ba": lambda: generators.ba_skewed(400, m_per=3, seed=3),
}


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("rpvo_max", [1, 4])
def test_bfs_matches_oracle(gname, rpvo_max):
    g = GRAPHS[gname]()
    root = int(g.src[0]) if g.num_edges else 0
    want = reference.bfs_levels(g, root)
    got, stats, part = bfs(g, root, num_shards=8, rpvo_max=rpvo_max)
    np.testing.assert_array_equal(got, want)
    assert int(stats.iterations) >= 1


@pytest.mark.parametrize("gname", ["er", "rmat", "ba", "star_in"])
@pytest.mark.parametrize("rpvo_max", [1, 4])
def test_sssp_matches_oracle(gname, rpvo_max):
    g = GRAPHS[gname]().with_random_weights(seed=11)
    root = int(g.src[0])
    want = reference.sssp_dijkstra(g, root)
    got, stats, part = sssp(g, root, num_shards=8, rpvo_max=rpvo_max)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gname", ["er", "rmat", "ba", "star_in"])
@pytest.mark.parametrize("rpvo_max", [1, 4])
def test_pagerank_matches_oracle(gname, rpvo_max):
    g = GRAPHS[gname]()
    want = reference.pagerank(g, iters=20)
    got, part = pagerank(g, iters=20, num_shards=8, rpvo_max=rpvo_max)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_partition_modes_agree():
    """'simple vertex' (home), RPVO (balanced), and Rhizomatic-RPVO all
    compute identical BFS levels — the data structure changes cost, not
    semantics (paper §3)."""
    g = generators.ba_skewed(300, m_per=4, seed=5)
    root = int(g.src[0])
    want = reference.bfs_levels(g, root)
    for ghost_alloc, rpvo_max in [("home", 1), ("balanced", 1),
                                  ("balanced", 8), ("vicinity", 8),
                                  ("random", 4)]:
        part = build_partition(g, PartitionConfig(
            num_shards=16, rpvo_max=rpvo_max, ghost_alloc=ghost_alloc,
            local_edge_list_size=8))
        got, _, _ = bfs(g, root, part=part)
        np.testing.assert_array_equal(got, want, err_msg=f"{ghost_alloc}/{rpvo_max}")


def test_rpvo_reduces_padded_width_on_out_skewed_graph():
    """The TPU-measurable RPVO win: with the 'simple vertex' layout a hub's
    out-edges all live at its home shard, so the padded per-shard edge
    width E_max is O(hub out-degree); RPVO ghost chunks rebalance it to
    ~E/S (DESIGN.md §2)."""
    g = generators.star(512, hub=0, inward=False)  # hub OUT-degree 511
    home = build_partition(g, PartitionConfig(num_shards=16, ghost_alloc="home"))
    rpvo = build_partition(g, PartitionConfig(
        num_shards=16, rpvo_max=1, ghost_alloc="balanced",
        local_edge_list_size=8))
    assert home.metrics["edge_balance"] > 4.0      # hub out-edges on one shard
    assert rpvo.metrics["edge_balance"] < 1.5      # near-perfect balance


def test_rhizome_splits_in_degree_hot_slot():
    """The rhizome win: a hub's inbox (in-degree load) is split across up
    to rpvo_max replica slots on distinct shards (paper §3.2, Eq. 1)."""
    g = generators.star(512, hub=0, inward=True)   # hub IN-degree 511
    no_rz = build_partition(g, PartitionConfig(
        num_shards=16, rpvo_max=1, ghost_alloc="balanced"))
    rz = build_partition(g, PartitionConfig(
        num_shards=16, rpvo_max=16, ghost_alloc="balanced",
        local_edge_list_size=8))
    assert no_rz.metrics["max_inbox_per_slot"] >= 511
    assert rz.metrics["max_inbox_per_slot"] <= int(np.ceil(511 / 16)) + 1
    # replicas land on many distinct shards
    hub_shards = rz.replica_shards_of(0)
    assert len(hub_shards) >= 4


def test_deferred_collapse_same_fixpoint():
    g = generators.ba_skewed(300, m_per=4, seed=7).with_random_weights(seed=7)
    root = int(g.src[0])
    want = reference.sssp_dijkstra(g, root)
    got, _, _ = sssp(g, root, num_shards=8, rpvo_max=8,
                     cfg=engine.EngineConfig(collapse="deferred"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_deferred_collapse_matches_eager_exactly():
    """Regression for the removed dead deferred-collapse branch in
    run_stacked: 'deferred' must produce the identical fixpoint to 'eager'
    (collapse timing changes cost, not the monotone fixpoint)."""
    g = generators.ba_skewed(300, m_per=4, seed=7).with_random_weights(seed=7)
    root = int(np.argmax(g.out_degrees()))
    for app in (bfs, sssp):
        eager, _, _ = app(g, root, num_shards=8, rpvo_max=8,
                          cfg=engine.EngineConfig(collapse="eager"))
        deferred, _, _ = app(g, root, num_shards=8, rpvo_max=8,
                             cfg=engine.EngineConfig(collapse="deferred"))
        np.testing.assert_array_equal(deferred, eager)


def test_fig6_style_stats_monotone_pruning():
    """Most delivered actions fail their predicate (paper Fig 6: only
    ~3-35% of actions perform work)."""
    g = generators.rmat(10, edge_factor=8, seed=4)
    root = int(g.src[0])
    _, stats, _ = bfs(g, root, num_shards=8, rpvo_max=4)
    msgs = int(stats.messages)
    work = int(stats.work_actions)
    assert msgs > 0
    assert work < msgs  # pruning happened
