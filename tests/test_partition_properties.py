"""Property-based partition invariants (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.partition import PartitionConfig, build_partition
from repro.graph.graph import COOGraph


def _rand_graph(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    return COOGraph(n, src, dst, rng.uniform(1, 5, m).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 120), m=st.integers(1, 500),
       shards=st.sampled_from([2, 4, 8]), rmax=st.sampled_from([1, 3, 8]),
       seed=st.integers(0, 2**30))
def test_partition_invariants(n, m, shards, rmax, seed):
    g = _rand_graph(n, m, seed)
    part = build_partition(g, PartitionConfig(
        num_shards=shards, rpvo_max=rmax, local_edge_list_size=4, seed=seed))
    # 1. every edge appears exactly once across shards
    assert int(part.edge_mask.sum()) == g.num_edges
    # 2. every vertex has a root replica; replica counts within bounds
    assert part.root_flat.shape == (n,)
    assert (part.num_replicas >= 1).all()
    assert (part.num_replicas <= rmax).all()
    # 3. edge destinations point at a replica of the true dst vertex
    S, R_max = part.S, part.R_max
    sv = part.slot_vertex.reshape(-1)
    em = part.edge_mask
    dst_v = sv[part.edge_dst_flat[em]]
    np.testing.assert_array_equal(dst_v, part.edge_dst_vertex[em])
    # 4. sources read from the true src vertex's root slot
    src_v = sv[part.edge_src_root_flat[em]]
    np.testing.assert_array_equal(src_v, part.edge_src_vertex[em])
    # 5. sibling closure: every replica's sibling set covers all replicas
    root_rows = part.root_flat // R_max
    root_cols = part.root_flat % R_max
    counted = part.sibling_mask[root_rows, root_cols].sum(axis=1)
    np.testing.assert_array_equal(counted, part.num_replicas)
    # 6. compact-exchange plan is a bijection onto the dense plan
    comp = part.edge_dst_compact[em]
    t = comp // part.P_t
    k = comp % part.P_t
    slot = part.inbox_slot_map[t, em.nonzero()[0] if False else None, k] \
        if False else None
    # map back via (target shard, source shard, k)
    src_shard = np.nonzero(em)[0]
    slot2 = part.inbox_slot_map[t, src_shard, k]
    flat2 = t * R_max + slot2
    np.testing.assert_array_equal(flat2, part.edge_dst_flat[em])
