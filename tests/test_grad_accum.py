"""Gradient accumulation == full-batch step (numerics), smaller live batch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.lm.models.model import Model
from repro.lm.train.optimizer import AdamW
from repro.lm.train.train_step import TrainState, make_train_step


def test_accum_matches_full_batch():
    cfg = dataclasses.replace(get_config("minitron-4b").reduced(), vocab=128)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=2)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    s_full = TrainState(params, opt.init(params), None)
    s_acc = TrainState(params, opt.init(params), None)
    step_full = jax.jit(make_train_step(model, opt))
    step_acc = jax.jit(make_train_step(model, opt, accum_steps=4))
    s_full, m_full = step_full(s_full, batch)
    s_acc, m_acc = step_acc(s_acc, batch)

    # CE mean-of-microbatch-means == full-batch mean (equal micro sizes)
    np.testing.assert_allclose(float(m_full["ce"]), float(m_acc["ce"]),
                               rtol=1e-5)
    # near-zero grads let Adam's normalizer amplify fp-summation noise into
    # full-step sign flips on isolated elements — bound absolutely by ~lr
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-4, atol=6e-4),
        s_full.params, s_acc.params)
