"""Worklist-launch differential tests (ISSUE 5 tentpole).

The fused kernel's dense grid launches every (num_sblk, num_chunks) cell
and early-exits the dead ones; ``grid_mode='worklist'`` launches a 1-D
grid over just the live cells, with per-cell dst-filtered tile lists and
2-slot tile reuse on the tiled path.  Every case here drives the
worklist twins against the dense kernels and the jnp oracle — min
semirings must agree **bit-identically**, sum up to the partial
scatter's reassociation — and asserts the host-side planner mirror
(``fused_grid_cells(grid_mode='worklist')``) EXACTLY equals the
kernel-side ``with_debug`` executed-cell / issued-DMA counters.
ISSUE 8 extends the suite with the device-compaction differential leg:
``grid_mode='device_worklist'`` builds the same live-(i, j) cell list ON
DEVICE (cumsum-scatter over the frontier chunk bitmap) — every case
asserts the device-compacted list equals the host ``plan_worklist``
output (order-normalized; exactly equal in the planner's j-major dense
order), under jit, across lane counts, and over real sharded
collectives in a subprocess.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.apps import bfs, sssp
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.kernels.fused_relax_reduce import (
    EBLK, SBLK, WL_PAD, WorklistPlanner, build_device_worklist,
    device_worklist_pad, fused_grid_cells,
    fused_relax_reduce_lanes_pallas, fused_relax_reduce_pallas,
    plan_worklist, select_kernel_path, smem_table_bytes,
)
from repro.kernels.ref import (
    fused_relax_reduce_lanes_ref, fused_relax_reduce_ref,
)
from repro.query.lanes import init_lane_values, run_stacked_lanes


def _hub_case(v, e, nseg, frontier_frac, seed, q=None):
    # NOT test_fused_tiled._skewed_case: sources here are drawn from a
    # small permuted hub pool (max v//8 distinct sources), concentrating
    # edges in few slot tiles so the per-cell dst filter and 2-slot
    # reuse have structure to bite on
    rng = np.random.default_rng(seed)
    shape = (v,) if q is None else (v, q)
    gval = rng.uniform(0.0, 10.0, shape).astype(np.float32)
    gchg = rng.random(shape) < frontier_frac
    src = rng.permutation(v)[rng.integers(0, max(v // 8, 1), e)] \
        .astype(np.int32)
    w = rng.uniform(0.1, 2.0, e).astype(np.float32)
    mask = rng.random(e) < 0.9
    ids = np.sort(rng.integers(0, nseg, e)).astype(np.int32)
    return tuple(jnp.asarray(x) for x in (gval, gchg, src, w, mask, ids))


def _wl_mirror(src, mask, ids, gchg, nseg, vblk=128, lane_width=1):
    gchg = np.asarray(gchg)
    if gchg.ndim == 2:
        gchg = gchg.any(axis=-1)
    return fused_grid_cells(np.asarray(ids), np.asarray(mask),
                            np.asarray(src), gchg, nseg, vblk=vblk,
                            lane_width=lane_width, grid_mode="worklist")


# --------------------------------------------------------------------------
# kernel-level differential: worklist == dense == ref, mirror exact
# --------------------------------------------------------------------------

WL_SHAPES = [
    # (v, e, nseg, vblk)
    (1, 1, 1, 128),
    (127, 300, 50, 128),
    (129, 300, 50, 128),
    (257, 2 * EBLK + 13, SBLK + 5, 128),
    (500, 3 * EBLK + 9, 2 * SBLK + 1, 128),
    (300, 1000, 400, 256),
]


@pytest.mark.parametrize("relax,kind", [
    ("add_w", "min"), ("add_one", "min"), ("mul_w", "sum")])
@pytest.mark.parametrize("v,e,nseg,vblk", WL_SHAPES)
def test_worklist_matches_dense_and_ref(relax, kind, v, e, nseg, vblk):
    gval, gchg, src, w, mask, ids = _hub_case(v, e, nseg, 0.4,
                                                 seed=v + e + nseg)
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, nseg,
                                  relax, kind)
    mirror = _wl_mirror(src, mask, ids, gchg, nseg, vblk=vblk)
    wl_p, dbg_p = fused_relax_reduce_pallas(
        gval, gchg, src, w, mask, ids, nseg, relax, kind,
        grid_mode="worklist", path="pinned", with_debug=True)
    wl_t, dbg_t = fused_relax_reduce_pallas(
        gval, gchg, src, w, mask, ids, nseg, relax, kind,
        grid_mode="worklist", path="tiled", vblk=vblk, with_debug=True)
    if kind == "min":
        dense = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids,
                                          nseg, relax, kind, path="pinned")
        np.testing.assert_array_equal(np.asarray(wl_p), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(wl_p), np.asarray(dense))
        np.testing.assert_array_equal(np.asarray(wl_t), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(wl_p), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(wl_t), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
    # the planner IS the launch: kernel counters must mirror it exactly
    assert int(dbg_p[0]) == mirror["wl_cells"]
    assert int(dbg_p[1]) == 0
    assert int(dbg_t[0]) == mirror["wl_cells"]
    assert int(dbg_t[1]) == mirror["wl_tile_dmas"]
    # dst filtering can only shrink the launch; reuse only the DMAs
    assert mirror["wl_cells"] <= mirror["fused_live"]
    assert mirror["wl_tile_dmas"] <= mirror["wl_tile_needed"]
    assert mirror["wl_tile_needed"] <= mirror["fused_tile_dmas"]
    assert mirror["wl_dma_bytes"] <= mirror["dma_bytes"]


@pytest.mark.parametrize("frontier_frac", [0.0, 0.05, 1.0])
def test_worklist_frontier_densities(frontier_frac):
    gval, gchg, src, w, mask, ids = _hub_case(400, 3 * EBLK + 9, 700,
                                                 frontier_frac, seed=5)
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, 700,
                                  "add_w", "min")
    got, dbg = fused_relax_reduce_pallas(
        gval, gchg, src, w, mask, ids, 700, "add_w", "min",
        grid_mode="worklist", path="tiled", vblk=128, with_debug=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    mirror = _wl_mirror(src, mask, ids, gchg, 700)
    if frontier_frac == 0.0:
        # an empty frontier launches only the WL_PAD dead pad cells
        assert mirror["wl_cells"] == 0
        assert mirror["wl_launched"] == WL_PAD
        assert int(dbg[0]) == 0 and int(dbg[1]) == 0
        assert np.all(np.asarray(got) == np.inf)
    else:
        assert int(dbg[0]) == mirror["wl_cells"] > 0


def test_worklist_padding_is_power_of_two_bucket():
    gval, gchg, src, w, mask, ids = _hub_case(500, 3 * EBLK + 9,
                                                 2 * SBLK + 1, 0.5, seed=8)
    mirror = _wl_mirror(src, mask, ids, gchg, 2 * SBLK + 1)
    launched = mirror["wl_launched"]
    assert launched >= max(mirror["wl_cells"], WL_PAD)
    assert launched & (launched - 1) == 0      # power of two
    assert launched < 2 * max(mirror["wl_cells"], WL_PAD)


def test_dst_filter_drops_cells_and_tiles():
    """Multi-dst-block case where a chunk's range spans blocks but each
    block only needs some of the chunk's tiles: the per-cell filter must
    strictly beat the per-chunk tile lists."""
    v, nseg = 1024, 4 * SBLK
    # hub sources in distinct vblk tiles, each aimed at ONE dst block
    src = np.concatenate([np.full(64, t * 128, np.int32)
                          for t in range(8)])
    ids = np.concatenate([np.full(64, b * SBLK, np.int32)
                          for b in range(4)] * 2)
    order = np.argsort(ids, kind="stable")
    src, ids = src[order], ids[order]
    e = src.shape[0]
    gval = jnp.asarray(np.random.default_rng(0)
                       .uniform(0, 10, v).astype(np.float32))
    gchg = jnp.ones(v, bool)
    w = jnp.ones(e, jnp.float32)
    mask = jnp.ones(e, bool)
    mirror = _wl_mirror(src, mask, ids, np.ones(v, bool), nseg)
    # every (block, tile) pairing is narrower than the chunk's union
    assert mirror["wl_tile_needed"] < mirror["fused_tile_dmas"]
    assert mirror["wl_dma_bytes"] < mirror["dma_bytes"]
    want = fused_relax_reduce_ref(gval, gchg, jnp.asarray(src), w, mask,
                                  jnp.asarray(ids), nseg, "add_w", "min")
    got, dbg = fused_relax_reduce_pallas(
        gval, gchg, jnp.asarray(src), w, mask, jnp.asarray(ids), nseg,
        "add_w", "min", grid_mode="worklist", path="tiled", vblk=128,
        with_debug=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(dbg[1]) == mirror["wl_tile_dmas"]


def test_j_major_tile_reuse_across_cells():
    """One edge chunk spanning several dst blocks, all edges from one
    slot tile: consecutive worklist cells share chunk j, so only the
    FIRST cell fetches the tile — the 2-slot reuse the planner schedules
    and the kernel executes."""
    v, nseg = 256, 4 * SBLK
    rng = np.random.default_rng(1)
    src = rng.integers(0, 100, 400).astype(np.int32)    # one 128-tile
    ids = np.sort(rng.integers(0, nseg, 400)).astype(np.int32)
    gval = jnp.asarray(rng.uniform(0, 10, v).astype(np.float32))
    gchg = jnp.ones(v, bool)
    w = jnp.ones(400, jnp.float32)
    mask = jnp.ones(400, bool)
    mirror = _wl_mirror(src, mask, ids, np.ones(v, bool), nseg)
    assert mirror["wl_cells"] > 1           # several dst blocks live
    assert mirror["wl_tile_dmas"] == 1      # but the tile rides once
    assert mirror["wl_tile_needed"] == mirror["wl_cells"]
    got, dbg = fused_relax_reduce_pallas(
        gval, gchg, jnp.asarray(src), w, mask, jnp.asarray(ids), nseg,
        "add_w", "min", grid_mode="worklist", path="tiled", vblk=128,
        with_debug=True)
    assert int(dbg[1]) == 1
    want = fused_relax_reduce_ref(gval, gchg, jnp.asarray(src), w, mask,
                                  jnp.asarray(ids), nseg, "add_w", "min")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_worklist_under_tracing_requires_plan():
    import jax
    gval, gchg, src, w, mask, ids = _hub_case(64, 100, 40, 0.5, seed=2)

    @jax.jit
    def f(gval, gchg):
        return fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids,
                                         40, "add_w", "min",
                                         grid_mode="worklist")

    with pytest.raises(ValueError, match="host-side"):
        f(gval, gchg)


# --------------------------------------------------------------------------
# laned worklist twins
# --------------------------------------------------------------------------

@pytest.mark.parametrize("q", [1, 3, 128])
def test_worklist_lanes_match_ref(q):
    v, e, nseg = (40, 200, 60) if q == 128 else (260, 900, 300)
    gval, gchg, src, w, mask, ids = _hub_case(v, e, nseg, 0.4,
                                                 seed=q, q=q)
    unitw = jnp.asarray(np.arange(q) % 2, jnp.int32)
    want = fused_relax_reduce_lanes_ref(gval, gchg, unitw, src, w, mask,
                                        ids, nseg, "add_w", "min")
    mirror = _wl_mirror(src, mask, ids, gchg, nseg)
    for path, vblk in (("pinned", None), ("tiled", 128)):
        got, dbg = fused_relax_reduce_lanes_pallas(
            gval, gchg, unitw, src, w, mask, ids, nseg, "add_w", "min",
            grid_mode="worklist", path=path, vblk=vblk, with_debug=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(dbg[0]) == mirror["wl_cells"]
        assert int(dbg[1]) == (mirror["wl_tile_dmas"] if path == "tiled"
                               else 0)


def test_worklist_lanes_sum_semiring_close():
    q = 5
    gval, gchg, src, w, mask, ids = _hub_case(100, 400, 150, 0.6,
                                                 seed=9, q=q)
    unitw = jnp.zeros(q, jnp.int32)
    want = fused_relax_reduce_lanes_ref(gval, gchg, unitw, src, w, mask,
                                        ids, 150, "mul_w", "sum")
    got = fused_relax_reduce_lanes_pallas(
        gval, gchg, unitw, src, w, mask, ids, 150, "mul_w", "sum",
        grid_mode="worklist", path="tiled", vblk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# SMEM-footprint guard (ISSUE 5 satellite)
# --------------------------------------------------------------------------

def test_smem_table_bytes_shapes():
    assert smem_table_bytes(10) == 10 * 3 * 4
    assert smem_table_bytes(10, t_max=4) == (10 * 3 + 10 * 5) * 4
    assert smem_table_bytes(10, t_max=0, wl_cells=8) == (30 + 17) * 4
    dense_tiled = smem_table_bytes(10, t_max=4)
    wl_tiled = smem_table_bytes(10, t_max=4, wl_cells=8)
    assert wl_tiled == (10 * 3 + 2 * 8 + 1 + 8 * 13) * 4
    assert wl_tiled > dense_tiled - 10 * 5 * 4   # chunk lists swap for cells


def test_select_kernel_path_smem_guard_widens_vblk():
    # 10k chunks of tile lists at vblk=128 overflow a 64 KiB SMEM budget;
    # the guard must warn and widen the tile until the tables fit
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        path, vblk = select_kernel_path(
            200_000, 1, 1024, n_chunks=10_000, smem_budget_bytes=64 * 1024)
    assert path == "tiled" and vblk > 128
    assert any("smem_budget_bytes" in str(w.message) for w in rec)
    t_max = -(-200_000 // vblk)
    assert smem_table_bytes(10_000, min(t_max, EBLK)) <= 64 * 1024 \
        or vblk >= 200_000
    # an ample budget leaves the decision untouched, silently
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        same = select_kernel_path(200_000, 1, 1024, n_chunks=10,
                                  smem_budget_bytes=10**9)
    assert same == ("tiled", 128) and not rec


def test_select_kernel_path_returns_info():
    path, vblk, info = select_kernel_path(
        10_000, 1, 8192, n_chunks=100, smem_budget_bytes=10**9,
        return_info=True)
    assert (path, vblk) == ("tiled", 1024)
    assert info["smem_table_bytes"] == smem_table_bytes(
        100, min(-(-10_240 // 1024), EBLK))
    assert fused_grid_cells(
        np.zeros(10, np.int64), np.ones(10, bool), np.zeros(10, np.int64),
        np.ones(16, bool), 8, vblk=128)["smem_table_bytes"] > 0


def test_planner_warns_when_worklist_tables_exceed_smem_budget():
    """The frontier-dependent worklist tables can only be priced at plan
    time: a planner armed with smem_budget_bytes warns once when a
    round's tables would overflow it."""
    gval, gchg, src, w, mask, ids = _hub_case(300, 2 * EBLK, 400, 1.0,
                                                 seed=4)
    planner = WorklistPlanner(np.asarray(ids), np.asarray(mask),
                              np.asarray(src), 400, num_slots=300,
                              path="tiled", vblk=128,
                              smem_budget_bytes=64)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        _, info = planner.plan(np.asarray(gchg))
        planner.plan(np.asarray(gchg))           # warned once, not twice
    assert info.smem_table_bytes > 64
    assert sum("smem_budget_bytes" in str(r.message) for r in rec) == 1
    # an unarmed planner stays silent
    quiet = WorklistPlanner(np.asarray(ids), np.asarray(mask),
                            np.asarray(src), 400, num_slots=300,
                            path="tiled", vblk=128)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        quiet.plan(np.asarray(gchg))
    assert not rec


def test_engine_config_grid_mode_validation():
    with pytest.raises(ValueError, match="grid_mode"):
        engine.EngineConfig(grid_mode="sparse")
    with pytest.raises(ValueError, match="smem_budget_bytes"):
        engine.EngineConfig(smem_budget_bytes=0)
    assert not engine.EngineConfig(grid_mode="worklist").wants_worklist
    assert engine.EngineConfig(grid_mode="worklist",
                               use_pallas=True).wants_worklist


# --------------------------------------------------------------------------
# engine-level: host-driven worklist rounds == traced dense rounds
# --------------------------------------------------------------------------

@pytest.mark.parametrize("exchange", ["dense", "compact"])
def test_engine_worklist_matches_dense(exchange):
    g = generators.ba_skewed(260, m_per=4, seed=9).with_random_weights(
        seed=9)
    root = int(np.argmax(g.out_degrees()))
    cfg_d = engine.EngineConfig(exchange=exchange, use_pallas=True)
    cfg_w = engine.EngineConfig(exchange=exchange, use_pallas=True,
                                grid_mode="worklist")
    cfg_a = engine.EngineConfig(exchange=exchange, use_pallas=True,
                                grid_mode="auto")
    for app in (bfs, sssp):
        out_d, st_d, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_d)
        for cfg in (cfg_w, cfg_a):
            out_w, st_w, _ = app(g, root, num_shards=8, rpvo_max=4,
                                 cfg=cfg)
            np.testing.assert_array_equal(out_w, out_d)
            assert int(st_w.messages) == int(st_d.messages)
            assert int(st_w.iterations) == int(st_d.iterations)
            assert int(st_w.work_actions) == int(st_d.work_actions)
    np.testing.assert_array_equal(
        bfs(g, root, num_shards=8, rpvo_max=4, cfg=cfg_w)[0],
        reference.bfs_levels(g, root))


def test_engine_worklist_tiled_budget_forced():
    """worklist × tiled composition: the slot table over the VMEM budget
    AND the sparse launch, bit-identical to the jnp path."""
    g = generators.ba_skewed(260, m_per=4, seed=9).with_random_weights(
        seed=9)
    root = int(np.argmax(g.out_degrees()))
    cfg_j = engine.EngineConfig()
    cfg_wt = engine.EngineConfig(use_pallas=True, grid_mode="worklist",
                                 vmem_budget_bytes=256)
    for app in (bfs, sssp):
        out_j, st_j, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_j)
        out_w, st_w, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_wt)
        np.testing.assert_array_equal(out_w, out_j)
        assert int(st_w.messages) == int(st_j.messages)


def test_worklist_launches_track_frontier_on_ring():
    """BFS on a ring: one live vertex per round, so every round's
    worklist is a handful of cells while the dense grid stays fixed —
    the ISSUE-5 acceptance shape (4 cells vs 96)."""
    g = generators.ring(4 * EBLK)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=1))
    sem = actions.BFS
    arrays = engine.DeviceArrays.from_partition(part)
    init = engine.init_values(part, sem, {0: 0.0})
    val = jnp.asarray(init)
    chg = sem.improved(val, jnp.full_like(val, sem.identity)) \
        & arrays.slot_valid
    cfg = engine.EngineConfig(use_pallas=True, grid_mode="worklist")
    planner = engine.launch_planner(part, cfg)
    total = part.S * part.R_max
    for _ in range(6):
        gchg = np.asarray(chg).reshape(-1)
        wl, info = planner.plan(gchg)
        mirror = fused_grid_cells(part.edge_dst_flat, part.edge_mask,
                                  part.edge_src_root_flat, gchg, total)
        assert info.cells <= mirror["fused_live"]
        assert info.launched <= max(2 * max(info.cells, 1), WL_PAD)
        assert info.launched < mirror["total_fused"] or \
            mirror["total_fused"] <= WL_PAD
        val, chg, _ = engine._fixpoint_round_stacked(
            sem, arrays, cfg, part.S, part.R_max, val, chg, worklist=wl)
    # deep in the ring walk the frontier is ONE vertex: a worklist of a
    # couple of cells vs the dense grid's full launch
    assert info.cells <= 4


@pytest.mark.parametrize("exchange", ["dense", "compact"])
def test_laned_engine_worklist_matches_dense(exchange):
    g = generators.ba_skewed(200, m_per=3, seed=4).with_random_weights(
        seed=4)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=4))
    init, unitw = init_lane_values(
        part, [("bfs", 0), ("sssp", 5), ("bfs", [1, 7])])
    cfg_d = engine.EngineConfig(exchange=exchange, use_pallas=True)
    cfg_w = engine.EngineConfig(exchange=exchange, use_pallas=True,
                                grid_mode="worklist")
    val_d, st_d = run_stacked_lanes(part, init, unitw, cfg=cfg_d)
    val_w, st_w = run_stacked_lanes(part, init, unitw, cfg=cfg_w)
    np.testing.assert_array_equal(np.asarray(val_w), np.asarray(val_d))
    np.testing.assert_array_equal(np.asarray(st_w.messages),
                                  np.asarray(st_d.messages))
    np.testing.assert_array_equal(np.asarray(st_w.rounds),
                                  np.asarray(st_d.rounds))
    np.testing.assert_array_equal(np.asarray(st_w.work_actions),
                                  np.asarray(st_d.work_actions))


# --------------------------------------------------------------------------
# ISSUE 8: device-side frontier compaction == host planner, exactly
# --------------------------------------------------------------------------

def _device_cells(gchg, src, mask, ids, nseg, num_slots, path="pinned",
                  vblk=None):
    """Build the device worklist and return its live (i, j) list plus the
    static launch length — all leaves are traced-capable arrays."""
    wl = build_device_worklist(
        jnp.asarray(gchg).reshape(-1), jnp.asarray(src),
        jnp.asarray(mask), jnp.asarray(ids), nseg, path, vblk, num_slots)
    n = int(wl.nlive[0])
    cells = list(zip(np.asarray(wl.wl_i)[:n].tolist(),
                     np.asarray(wl.wl_j)[:n].tolist()))
    return cells, int(np.asarray(wl.wl_i).shape[0])


def _host_cells(gchg, src, mask, ids, nseg, num_slots):
    """The host oracle: ``plan_worklist`` without the dst filter (the
    device compaction keeps every live cell) — j-major dense order."""
    wl, info = plan_worklist(
        np.asarray(ids), np.asarray(mask), np.asarray(src),
        np.asarray(gchg).reshape(-1), nseg, num_slots=num_slots,
        dst_filter=False)
    n = int(wl.nlive[0])
    return list(zip(np.asarray(wl.wl_i)[:n].tolist(),
                    np.asarray(wl.wl_j)[:n].tolist())), info


@pytest.mark.parametrize("v,e,nseg,vblk", WL_SHAPES)
def test_device_compaction_equals_host_plan(v, e, nseg, vblk):
    gval, gchg, src, w, mask, ids = _hub_case(v, e, nseg, 0.4,
                                              seed=v + e + nseg)
    dev, launched = _device_cells(gchg, src, mask, ids, nseg, v)
    host, _ = _host_cells(gchg, src, mask, ids, nseg, v)
    # same j-major dense order, not merely the same set
    assert dev == host
    assert sorted(dev) == sorted(host)          # order-normalized too
    assert launched == device_worklist_pad(e, nseg)
    # the dense early-exit grid's live count is the same population
    mirror = fused_grid_cells(np.asarray(ids), np.asarray(mask),
                              np.asarray(src), np.asarray(gchg), nseg,
                              grid_mode="device_worklist")
    assert len(dev) == mirror["wl_cells"] == mirror["fused_live"]
    assert mirror["wl_launched"] == launched


@pytest.mark.parametrize("case", ["empty", "single_vertex",
                                  "tile_boundary", "skewed_hub"])
def test_device_compaction_edge_cases(case):
    v, e, nseg = 300, 2 * EBLK + 9, 2 * SBLK + 1
    rng = np.random.default_rng(17)
    src = rng.integers(0, v, e).astype(np.int32)
    mask = rng.random(e) < 0.9
    ids = np.sort(rng.integers(0, nseg, e)).astype(np.int32)
    if case == "empty":
        gchg = np.zeros(v, bool)
    elif case == "single_vertex":
        gchg = np.zeros(v, bool)
        gchg[int(src[0])] = True
    elif case == "tile_boundary":
        # live exactly at the EBLK chunk seam: edge EBLK-1 and EBLK
        gchg = np.zeros(v, bool)
        gchg[src[EBLK - 1]] = True
        gchg[src[EBLK]] = True
        mask[:] = True
    else:                                        # skewed_hub
        hub = int(np.bincount(src, minlength=v).argmax())
        gchg = np.zeros(v, bool)
        gchg[hub] = True
    dev, launched = _device_cells(gchg, src, mask, ids, nseg, v)
    host, _ = _host_cells(gchg, src, mask, ids, nseg, v)
    assert dev == host
    assert launched == device_worklist_pad(e, nseg)
    if case == "empty":
        assert dev == []
        # the static pad still launches; every cell is a masked no-op
        gval = jnp.asarray(rng.uniform(0, 10, v).astype(np.float32))
        out, dbg = fused_relax_reduce_pallas(
            gval, jnp.asarray(gchg), jnp.asarray(src),
            jnp.ones(e, jnp.float32), jnp.asarray(mask),
            jnp.asarray(ids), nseg, "add_w", "min",
            grid_mode="device_worklist", with_debug=True)
        assert np.all(np.asarray(out) == np.inf)
        assert int(dbg[0]) == 0 and int(dbg[1]) == 0


def test_device_compaction_under_jit():
    """The whole point: compaction traces — the same call fails for the
    host-planned mode (see test_worklist_under_tracing_requires_plan)."""
    gval, gchg, src, w, mask, ids = _hub_case(64, 100, 40, 0.5, seed=2)

    @jax.jit
    def f(gval, gchg):
        return fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids,
                                         40, "add_w", "min",
                                         grid_mode="device_worklist")

    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, 40,
                                  "add_w", "min")
    np.testing.assert_array_equal(np.asarray(f(gval, gchg)),
                                  np.asarray(want))


@pytest.mark.parametrize("q", [1, 3, 128])
def test_device_compaction_lanes(q):
    v, e, nseg = (40, 200, 60) if q == 128 else (260, 900, 300)
    gval, gchg, src, w, mask, ids = _hub_case(v, e, nseg, 0.4,
                                              seed=q, q=q)
    unitw = jnp.asarray(np.arange(q) % 2, jnp.int32)
    want = fused_relax_reduce_lanes_ref(gval, gchg, unitw, src, w, mask,
                                        ids, nseg, "add_w", "min")
    or_chg = np.asarray(gchg).any(axis=-1)
    host, _ = _host_cells(or_chg, src, mask, ids, nseg, v)
    for path, vblk in (("pinned", None), ("tiled", 128)):
        got, dbg = fused_relax_reduce_lanes_pallas(
            gval, gchg, unitw, src, w, mask, ids, nseg, "add_w", "min",
            grid_mode="device_worklist", path=path, vblk=vblk,
            with_debug=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # kernel executed exactly the host-oracle live cells
        assert int(dbg[0]) == len(host)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(v=st.integers(2, 400),
           e=st.integers(1, 2 * EBLK + 40),
           nseg=st.integers(1, 2 * SBLK + 9),
           frontier=st.floats(0.0, 1.0),
           seed=st.integers(0, 2**31 - 1))
    def test_device_compaction_hypothesis(v, e, nseg, frontier, seed):
        """Randomized sweep: device-compacted live cells equal the host
        plan on arbitrary skew / frontier density / tile alignment."""
        gval, gchg, src, w, mask, ids = _hub_case(v, e, nseg, frontier,
                                                  seed=seed)
        dev, launched = _device_cells(gchg, src, mask, ids, nseg, v)
        host, _ = _host_cells(gchg, src, mask, ids, nseg, v)
        assert dev == host
        assert launched == device_worklist_pad(e, nseg)


SHARDED_DEVICE_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import actions, engine
    from repro.core.partition import PartitionConfig, build_partition
    from repro.graph import generators

    assert len(jax.devices()) == 8
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

    g = generators.ba_skewed(400, m_per=4, seed=11).with_random_weights(
        seed=11)
    part = build_partition(g, PartitionConfig(num_shards=8, rpvo_max=2))
    init = engine.init_values(part, actions.SSSP, {0: 0.0})

    base = dict(use_pallas=True, pallas_mode="fused")
    val_d, st_d = engine.run_sharded(
        actions.SSSP, part, init, mesh, ("data", "model"),
        engine.EngineConfig(grid_mode="dense", **base))
    val_dev, st_dev = engine.run_sharded(
        actions.SSSP, part, init, mesh, ("data", "model"),
        engine.EngineConfig(grid_mode="device_worklist", **base))
    # host-planned grid_mode='worklist' cannot trace under shard_map:
    # the runner must warn ONCE and route to the device compaction
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        val_w, st_w = engine.run_sharded(
            actions.SSSP, part, init, mesh, ("data", "model"),
            engine.EngineConfig(grid_mode="worklist", **base))
        engine.run_sharded(
            actions.SSSP, part, init, mesh, ("data", "model"),
            engine.EngineConfig(grid_mode="worklist", **base))
    routed = [w for w in rec if "device_worklist" in str(w.message)]
    assert len(routed) == 1, [str(w.message) for w in rec]

    np.testing.assert_array_equal(np.asarray(val_dev), np.asarray(val_d))
    np.testing.assert_array_equal(np.asarray(val_w), np.asarray(val_d))
    for f in ("iterations", "messages", "work_actions", "pruned_actions"):
        assert int(getattr(st_dev, f)) == int(getattr(st_d, f))
        assert int(getattr(st_w, f)) == int(getattr(st_d, f))
    print("SHARDED_DEVICE_WL_OK it=%d" % int(st_dev.iterations))
""")


def test_device_compaction_sharded_8dev_subprocess():
    """8-host-device sharded parity: the device-compacted worklist grid
    executes INSIDE run_sharded's traced collective loop and matches the
    dense sharded run exactly; 'worklist' warns once and routes."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = os.environ.copy()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"    # see test_engine_sharded.py
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_DEVICE_CHILD], env=env,
        capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "SHARDED_DEVICE_WL_OK" in out.stdout


def test_engine_device_worklist_matches_dense():
    """run_stacked under grid_mode='device_worklist': the whole fixpoint
    is one traced while_loop dispatch; values and stats equal dense."""
    g = generators.ba_skewed(260, m_per=4, seed=9).with_random_weights(
        seed=9)
    root = int(np.argmax(g.out_degrees()))
    cfg_d = engine.EngineConfig(use_pallas=True)
    cfg_dev = engine.EngineConfig(use_pallas=True,
                                  grid_mode="device_worklist")
    for app in (bfs, sssp):
        out_d, st_d, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_d)
        out_v, st_v, _ = app(g, root, num_shards=8, rpvo_max=4,
                             cfg=cfg_dev)
        np.testing.assert_array_equal(out_v, out_d)
        for f in ("iterations", "messages", "work_actions",
                  "pruned_actions"):
            assert int(getattr(st_v, f)) == int(getattr(st_d, f))


def test_planner_live_fraction_and_auto_threshold():
    gval, gchg, src, w, mask, ids = _hub_case(300, 1000, 400, 1.0,
                                                 seed=3)
    planner = WorklistPlanner(np.asarray(ids), np.asarray(mask),
                              np.asarray(src), 400, num_slots=300)
    dense_frac = planner.live_fraction(np.asarray(gchg))
    assert 0.0 < dense_frac <= 1.0
    assert planner.live_fraction(np.zeros(300, bool)) == 0.0
    cfg_auto = engine.EngineConfig(use_pallas=True, grid_mode="auto")
    # a dead frontier is maximally sparse -> auto must plan a worklist
    assert engine.plan_round_worklist(
        planner, cfg_auto, np.zeros(300, bool)) is not None
    if dense_frac >= engine.WORKLIST_AUTO_THRESHOLD:
        assert engine.plan_round_worklist(
            planner, cfg_auto, np.asarray(gchg)) is None
