"""Layer-level unit + property tests: RoPE, RMSNorm, attention paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.lm.configs import get_config
from repro.lm.models import layers as L


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 7.0
    y = L.rms_norm(x, jnp.ones(32), 1e-6)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(shift=st.integers(1, 64))
def test_rope_relative_property(shift):
    """RoPE: <q_m, k_n> depends only on m-n (relative positions)."""
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 1, 1, 64))
    def score(m, n):
        qm = L.rope(q, jnp.asarray([m])[None], 10000.0)
        kn = L.rope(k, jnp.asarray([n])[None], 10000.0)
        return float(jnp.einsum("bshd,bshd->", qm, kn))
    assert np.isclose(score(5, 5 + shift), score(90, 90 + shift), rtol=1e-4,
                      atol=1e-5)


def test_chunked_attention_equals_plain():
    """Online-softmax chunked attention == plain attention (the §Perf
    'attn_chunked' opt is numerics-preserving)."""
    key = jax.random.PRNGKey(3)
    B, Sq, Sk, H, hd = 2, 48, 48, 4, 16
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, Sk, 2, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, Sk, 2, hd))
    qp, kp = jnp.arange(Sq), jnp.arange(Sk)
    plain = L._plain_attention(q, k, v, L.causal_mask, qp, kp, hd ** -0.5)
    import repro.lm.models.layers as LL
    old = LL.KV_CHUNK
    LL.KV_CHUNK = 16
    try:
        chunk = L._chunked_attention(q, k, v, L.causal_mask, qp, kp, hd ** -0.5)
    finally:
        LL.KV_CHUNK = old
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunk),
                               rtol=2e-4, atol=2e-5)


def test_chunked_ce_equals_dense():
    from repro.lm.models.model import Model
    cfg = get_config("minitron-4b").reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks}
    loss_d, _ = model.loss(params, batch)
    cfg_c = dataclasses.replace(cfg, opts=("chunked_ce",))
    model_c = Model(cfg_c)
    loss_c, _ = model_c.loss(params, batch)
    np.testing.assert_allclose(float(loss_d), float(loss_c), rtol=1e-5)


def test_prefix_lm_mask():
    fn = L.prefix_lm_mask(4)
    qp = jnp.arange(8)[:, None]
    kp = jnp.arange(8)[None, :]
    m = np.asarray(fn(qp, kp))
    assert m[0, 3]          # prefix visible everywhere
    assert not m[2, 6]      # future suffix hidden
    assert m[6, 5]          # causal within suffix


def test_scan_unroll_preserves_mamba_numerics():
    import repro.lm.models.ssm as S
    from repro.lm.models.layers import split_tree
    cfg = get_config("jamba-v0.1-52b").reduced()
    params, _ = split_tree(S.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y0, s0 = S.apply_mamba(params, cfg, x)
    cfg_u = dataclasses.replace(cfg, opts=("scan_unroll",))
    y1, s1 = S.apply_mamba(params, cfg_u, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(s0["ssm"]), np.asarray(s1["ssm"]),
                               rtol=1e-5, atol=1e-6)
