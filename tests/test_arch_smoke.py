"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward/train step (and a prefill+decode step) on CPU — shapes right,
no NaNs. Full configs are exercised only by the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lm.configs import ARCHS
from repro.lm.models.model import Model


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "enc_dec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params, axes = model.init(key)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), metrics
    # one grad step: finite grads with matching structure
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_smoke(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params, _ = model.init(key)
    B, S = 2, 8
    batch = _batch(cfg, key, B=B, S=S)
    max_len = S + 4 + (cfg.n_patches if cfg.family == "vlm" else 0)
    caches = model.init_cache(B, max_len)
    logits, caches = jax.jit(model.prefill)(params, batch, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    start = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits2, caches = jax.jit(model.decode_step)(
        params, tok, caches, jnp.asarray(start, jnp.int32))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_prefill_dense():
    """Incremental decode logits == full-sequence forward logits (the KV
    cache is exact) for a dense arch."""
    cfg = ARCHS["phi3-medium-14b"].reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params, _ = model.init(key)
    B, S = 2, 10
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # full forward logits at position S-1 (predicting token S)
    full_caches = model.init_cache(B, S)
    logits_full, _ = model.prefill(params, {"tokens": toks}, full_caches)

    # prefill S-1, then decode token S-1
    caches = model.init_cache(B, S)
    _, caches = model.prefill(params, {"tokens": toks[:, : S - 1]}, caches)
    logits_inc, _ = model.decode_step(
        params, toks[:, S - 1 :], caches, jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_inc[:, -1], np.float32), rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_ssm():
    """Recurrent-state handoff is exact for the xLSTM arch."""
    cfg = ARCHS["xlstm-125m"].reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(3)
    params, _ = model.init(key)
    B, S = 2, 9
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_caches = model.init_cache(B, S)
    logits_full, _ = model.prefill(params, {"tokens": toks}, full_caches)
    caches = model.init_cache(B, S)
    _, caches = model.prefill(params, {"tokens": toks[:, : S - 1]}, caches)
    logits_inc, _ = model.decode_step(
        params, toks[:, S - 1 :], caches, jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_inc[:, -1], np.float32), rtol=5e-4, atol=5e-4)
