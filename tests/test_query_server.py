"""QueryServer continuous batching (ISSUE 2 tentpole).

Covers: mixed-kind request correctness vs numpy references, eviction the
round a lane converges (per-request rounds == the solo run's), mid-flight
admission into a lane freed while other lanes are still live (tested, not
demoed — the acceptance criterion), and no head-of-line blocking (a
short query completes before a long one admitted earlier).
"""
import numpy as np
import pytest

from repro.core import engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.graph.graph import COOGraph
from repro.query import QueryServer

UNREACHED = np.iinfo(np.int32).max


def _path_graph(n):
    src = np.arange(n - 1, dtype=np.int32)
    return COOGraph(n, src, (src + 1).astype(np.int32), None)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_server_mixed_kinds_match_references(use_pallas):
    g = generators.rmat(7, edge_factor=5, seed=5).with_random_weights(seed=5)
    deg = np.argsort(-g.out_degrees())
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=2))
    srv = QueryServer(part, n_lanes=3, ppr_lanes=2,
                      cfg=engine.EngineConfig(use_pallas=use_pallas))
    r0, r1, r2 = int(deg[0]), int(deg[1]), int(deg[4])
    q_bfs = srv.submit("bfs", r0)
    q_sssp = srv.submit("sssp", r1)
    q_reach = srv.submit("reachability", r2)
    q_msbfs = srv.submit("bfs", [r1, r2])          # multi-source
    results = srv.run()
    assert set(results) == {q_bfs, q_sssp, q_reach, q_msbfs}

    np.testing.assert_array_equal(results[q_bfs].values,
                                  reference.bfs_levels(g, r0))
    ref_d = reference.sssp_dijkstra(g, r1)
    finite = np.isfinite(ref_d)
    np.testing.assert_allclose(results[q_sssp].values[finite],
                               ref_d[finite], rtol=1e-5)
    assert not np.isfinite(results[q_sssp].values[~finite]).any()
    np.testing.assert_array_equal(
        results[q_reach].values,
        reference.bfs_levels(g, r2) != UNREACHED)
    ms_want = np.minimum(reference.bfs_levels(g, r1),
                         reference.bfs_levels(g, r2))
    np.testing.assert_array_equal(results[q_msbfs].values, ms_want)


def test_server_ppr_requests_match_reference():
    g = generators.rmat(7, edge_factor=5, seed=8)
    from repro.apps.pagerank import _pr_graph
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=4, rpvo_max=2))
    deg = np.argsort(-g.out_degrees())
    srv = QueryServer(part, n_lanes=1, ppr_lanes=2)
    qa = srv.submit("ppr", int(deg[0]), damping=0.85, tol=1e-9)
    qb = srv.submit("ppr", int(deg[3]), damping=0.6, tol=1e-9)
    results = srv.run()
    for qid, seed, d in ((qa, int(deg[0]), 0.85), (qb, int(deg[3]), 0.6)):
        want = reference.personalized_pagerank(g, seed, d, tol=1e-12)
        np.testing.assert_allclose(results[qid].values, want,
                                   rtol=1e-4, atol=1e-7)


def test_server_evicts_on_convergence_with_solo_round_counts():
    """A lane is freed the round its query converges; the per-request
    round count equals the solo engine run's iteration count."""
    g = generators.rmat(7, edge_factor=4, seed=2).with_random_weights(seed=2)
    deg = np.argsort(-g.out_degrees())
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=2))
    srv = QueryServer(part, n_lanes=2)
    roots = [int(deg[0]), int(deg[2])]
    qids = [srv.submit("bfs", r) for r in roots]
    results = srv.run()
    from repro.apps import bfs as solo_bfs
    for qid, root in zip(qids, roots):
        _, solo_stats, _ = solo_bfs(g, root, part=part)
        assert results[qid].rounds == int(solo_stats.iterations)
        assert results[qid].messages == int(solo_stats.messages)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_server_admits_into_lane_freed_mid_flight(use_pallas):
    """The acceptance criterion: with both lanes busy, a queued request
    must be admitted into the lane a short query frees while the long
    query is STILL running — and the short queries must not wait behind
    the long one (no head-of-line blocking)."""
    n = 40
    g = _path_graph(n)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=1))
    srv = QueryServer(part, n_lanes=2,
                      cfg=engine.EngineConfig(use_pallas=use_pallas))
    q_long = srv.submit("bfs", 0)          # n-1 rounds down the path
    q_short1 = srv.submit("bfs", n - 3)    # 2 rounds
    q_short2 = srv.submit("bfs", n - 5)    # queued: both lanes busy
    results = srv.run()
    assert set(results) == {q_long, q_short1, q_short2}

    long_r, s1, s2 = results[q_long], results[q_short1], results[q_short2]
    # short2 was admitted into short1's freed lane while long was live...
    assert s2.admitted_tick > 0                      # had to wait for a lane
    assert s2.admitted_tick > s1.completed_tick      # freed by short1
    assert s2.admitted_tick < long_r.completed_tick  # mid-flight, long live
    assert s2.lane == s1.lane and s2.lane != long_r.lane
    # ...and neither short query waited for the long one to finish
    assert s1.completed_tick < long_r.completed_tick
    assert s2.completed_tick < long_r.completed_tick

    np.testing.assert_array_equal(long_r.values, reference.bfs_levels(g, 0))
    np.testing.assert_array_equal(s1.values,
                                  reference.bfs_levels(g, n - 3))
    np.testing.assert_array_equal(s2.values,
                                  reference.bfs_levels(g, n - 5))
    # n-1 relax rounds down the path + the final no-improvement round that
    # detects convergence (same count as the solo engine's `iterations`)
    assert long_r.rounds == n


def test_server_occupancy_and_queue_drain():
    """More requests than lanes: everything completes, occupancy is
    tracked, and lanes never exceed capacity."""
    g = generators.rmat(7, edge_factor=4, seed=4)
    deg = np.argsort(-g.out_degrees())
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=2))
    srv = QueryServer(part, n_lanes=2)
    qids = [srv.submit("bfs", int(deg[i])) for i in range(6)]
    results = srv.run()
    assert set(results) == set(qids)
    assert srv.queue == []
    assert 0.0 < srv.occupancy() <= 1.0
    assert max(srv.occupancy_trace) <= 2     # min pool capacity respected
    for qid in qids:
        assert results[qid].latency_s >= 0.0


def test_server_rejects_unknown_kind():
    g = generators.ring(16)
    part = build_partition(g, PartitionConfig(num_shards=2))
    srv = QueryServer(part, n_lanes=1)
    with pytest.raises(ValueError, match="unknown query kind"):
        srv.submit("pagerank-global", 0)


def test_server_rejects_multi_seed_ppr():
    """ppr personalization is single-seed; a seed list must fail loudly at
    submit instead of silently truncating to the first vertex."""
    g = generators.ring(16)
    part = build_partition(g, PartitionConfig(num_shards=2))
    srv = QueryServer(part, n_lanes=1)
    with pytest.raises(ValueError, match="single personalization seed"):
        srv.submit("ppr", [0, 1])


def test_server_rejects_submit_into_empty_pool():
    """A request whose pool has zero lanes could never be admitted; it
    must fail at submit, not sit in the queue while run() spins."""
    g = generators.ring(16)
    part = build_partition(g, PartitionConfig(num_shards=2))
    srv = QueryServer(part, n_lanes=1, ppr_lanes=0)
    with pytest.raises(ValueError, match="no lanes"):
        srv.submit("ppr", 0)
