"""Crash-safe fixpoints (ISSUE 10): the chaos differential suite.

Every fault class x layout: inject a deterministic fault, let the
resilient driver detect and recover, and assert the terminal result
equals a fault-free oracle — min-semiring values BIT-identical, the
accounting counters (rounds/messages/work) exactly equal (counters ride
in the checkpoint tree), delta-PageRank within reassociation tolerance.
Plus: checkpoint/restore round trips (engine, serving, streaming WAL),
shrink-on-death field-for-field partition equality, graceful
degradation, and post-recovery flight-recorder records that still match
the planner/kernel mirrors.
"""
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.core.resilient import (
    LanesTask, PagerankTask, StackedTask, migrate_values, run_resilient,
    shrink_partition)
from repro.core.streaming import StreamingGraph
from repro.graph import generators
from repro.runtime.chaos import (
    ChaosEvent, ChaosPlan, FaultDetected, RecoveryPolicy)
from repro.runtime.elastic import ShardPool

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _case(scale=7, seed=5, shards=4, rpvo=2):
    g = generators.rmat(scale, edge_factor=5, seed=seed)
    g = g.with_random_weights(seed=seed)
    part = build_partition(g, PartitionConfig(num_shards=shards,
                                              rpvo_max=rpvo))
    root = int(np.argsort(-g.out_degrees())[0])
    return g, part, root


def _sssp_init(part, root):
    return engine.init_values(part, actions.SSSP, {root: 0.0})


# --------------------------------------------------------------------------
# clean runs: the resilient driver IS the shipped runner when no fault fires
# --------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    engine.EngineConfig(),
    engine.EngineConfig(use_pallas=True, grid_mode="worklist"),
    engine.EngineConfig(use_pallas=True, grid_mode="device_worklist"),
], ids=["dense", "worklist", "device_worklist"])
def test_resilient_no_chaos_equals_run_stacked(cfg):
    g, part, root = _case()
    init = _sssp_init(part, root)
    want, wstats = engine.run_stacked(actions.SSSP, part, init, cfg)
    got, stats, report = run_resilient(
        StackedTask(actions.SSSP, part, init, cfg))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert report.status == "ok" and not report.faults
    assert (stats.iterations, stats.messages, stats.work_actions) == \
        (wstats.iterations, wstats.messages, wstats.work_actions)


def test_resilient_pagerank_clean_equals_delta_runner():
    g, part, _ = _case(seed=8)
    want, wstats = engine.run_pagerank_delta(part, 0.85, 1e-6)
    got, stats, report = run_resilient(PagerankTask(part, 0.85, 1e-6))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert report.status == "ok"
    assert stats.iterations == wstats.iterations
    assert stats.messages == wstats.messages


# --------------------------------------------------------------------------
# the fault-class differential: every injected fault -> typed recovery,
# values equal the fault-free oracle, accounting totals exactly equal
# --------------------------------------------------------------------------

FAULTS = [
    ("kill_shard", "restore"),
    ("corrupt_tile", "restore"),
    ("drop_inbox", "retry"),
    ("dup_inbox", "retry"),
    ("delay_shard", None),       # a straggler is NOT a fault
]


@pytest.mark.parametrize("kind,action", FAULTS,
                         ids=[k for k, _ in FAULTS])
@pytest.mark.parametrize("grid", ["dense", "device_worklist"])
def test_fault_differential_stacked(kind, action, grid):
    cfg = engine.EngineConfig(use_pallas=(grid != "dense"),
                              grid_mode=grid)
    g, part, root = _case()
    init = _sssp_init(part, root)
    want, wstats = engine.run_stacked(actions.SSSP, part, init, cfg)
    assert wstats.iterations > 4, "case too small to inject at round 3"
    chaos = ChaosPlan(events=(ChaosEvent(round=3, kind=kind, shard=2,
                                         rounds=1),))
    got, stats, report = run_resilient(
        StackedTask(actions.SSSP, part, init, cfg), chaos=chaos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if action is None:
        assert report.status == "ok" and not report.faults
        assert stats.messages == wstats.messages
        assert stats.iterations == wstats.iterations
    else:
        assert report.status == "recovered"
        assert any(f.kind == kind and f.action == action
                   for f in report.faults)
        # counters ride the recovery: totals equal the uninterrupted run
        assert stats.messages == wstats.messages
        assert stats.iterations == wstats.iterations
        assert stats.work_actions == wstats.work_actions


def test_fault_differential_pagerank():
    g, part, _ = _case(seed=8)
    want, wstats = engine.run_pagerank_delta(part, 0.85, 1e-6)
    chaos = ChaosPlan(events=(
        ChaosEvent(round=2, kind="corrupt_tile", shard=1),
        ChaosEvent(round=4, kind="drop_inbox", shard=0)))
    got, stats, report = run_resilient(PagerankTask(part, 0.85, 1e-6),
                                       chaos=chaos)
    assert report.status == "recovered"
    kinds = {f.kind for f in report.faults}
    assert "corrupt_tile" in kinds
    # sum semiring: reassociation tolerance (bit-exact in practice on
    # one device — the traced reductions replay unreordered)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-9)
    assert stats.iterations == wstats.iterations
    assert stats.messages == wstats.messages


def test_fault_differential_lanes():
    from repro.query import lanes as L
    g, part, root = _case()
    roots = np.argsort(-g.out_degrees())[:3]
    queries = [("sssp", int(roots[0])), ("bfs", int(roots[1])),
               ("sssp", int(roots[2]))]
    init, unitw = L.init_lane_values(part, queries)
    want, wstats = L.run_stacked_lanes(part, init, unitw)
    chaos = ChaosPlan(events=(
        ChaosEvent(round=2, kind="corrupt_tile", shard=3),
        ChaosEvent(round=3, kind="dup_inbox", shard=1)))
    got, stats, report = run_resilient(
        LanesTask(part, init, unitw), chaos=chaos)
    assert report.status == "recovered"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # laned message counts: resilient total equals the lane-summed stats
    assert stats.messages == int(np.asarray(wstats.messages).sum())


def test_chaos_exhaustive_kinds_single_run():
    """One run surviving the whole fault zoo still lands on the oracle."""
    g, part, root = _case(scale=8, seed=11)
    init = _sssp_init(part, root)
    cfg = engine.EngineConfig()
    want, wstats = engine.run_stacked(actions.SSSP, part, init, cfg)
    chaos = ChaosPlan(events=(
        ChaosEvent(round=2, kind="delay_shard", shard=0, rounds=1),
        ChaosEvent(round=3, kind="drop_inbox", shard=2),
        ChaosEvent(round=4, kind="corrupt_tile", shard=1),
        ChaosEvent(round=5, kind="dup_inbox", shard=3),
        ChaosEvent(round=6, kind="kill_shard", shard=0)))
    policy = RecoveryPolicy(max_retries=2, max_restores=4)
    got, stats, report = run_resilient(
        StackedTask(actions.SSSP, part, init, cfg), chaos=chaos,
        policy=policy)
    assert report.status == "recovered"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert stats.messages == wstats.messages
    assert stats.iterations == wstats.iterations


# --------------------------------------------------------------------------
# checkpoint/restore through a real CheckpointManager
# --------------------------------------------------------------------------

@pytest.mark.parametrize("checkpoint_every", [1, 3])
def test_checkpointed_restore_exact(checkpoint_every, tmp_path):
    g, part, root = _case(scale=8, seed=2)
    cfg = engine.EngineConfig(checkpoint_every=checkpoint_every)
    init = _sssp_init(part, root)
    want, wstats = engine.run_stacked(actions.SSSP, part, init,
                                         engine.EngineConfig())
    chaos = ChaosPlan(events=(
        ChaosEvent(round=6, kind="kill_shard", shard=1),))
    mgr = CheckpointManager(str(tmp_path))
    got, stats, report = run_resilient(
        StackedTask(actions.SSSP, part, init, cfg), chaos=chaos,
        manager=mgr)
    assert report.status == "recovered"
    assert report.checkpoints_written > 0
    # restore resumes from the last boundary: <= K rounds replayed
    assert 0 <= report.rounds_lost <= checkpoint_every + \
        RecoveryPolicy().heartbeat_window
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert stats.iterations == wstats.iterations
    assert stats.messages == wstats.messages


def test_restore_without_manager_uses_round0():
    g, part, root = _case()
    init = _sssp_init(part, root)
    want, wstats = engine.run_stacked(actions.SSSP, part, init,
                                         engine.EngineConfig())
    chaos = ChaosPlan(events=(
        ChaosEvent(round=4, kind="corrupt_tile", shard=0),))
    got, stats, report = run_resilient(
        StackedTask(actions.SSSP, part, init), chaos=chaos)
    assert report.status == "recovered"
    assert report.rounds_lost >= 3     # all the way back to round 0
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert stats.messages == wstats.messages


# --------------------------------------------------------------------------
# graceful degradation + typed raise
# --------------------------------------------------------------------------

def test_degraded_after_budget_exhaustion():
    g, part, root = _case()
    init = _sssp_init(part, root)
    chaos = ChaosPlan(events=(
        ChaosEvent(round=2, kind="corrupt_tile", shard=0),))
    got, stats, report = run_resilient(
        StackedTask(actions.SSSP, part, init), chaos=chaos,
        policy=RecoveryPolicy(max_restores=0))
    assert report.status == "degraded"
    assert any(f.action == "degrade" for f in report.faults)
    assert np.asarray(got).shape == (part.S, part.R_max)  # partial values


def test_degrade_false_raises_typed():
    g, part, root = _case()
    init = _sssp_init(part, root)
    chaos = ChaosPlan(events=(
        ChaosEvent(round=2, kind="corrupt_tile", shard=0),))
    with pytest.raises(FaultDetected) as ei:
        run_resilient(StackedTask(actions.SSSP, part, init), chaos=chaos,
                      policy=RecoveryPolicy(max_restores=0,
                                            degrade=False))
    assert ei.value.kind == "corrupt_tile"


# --------------------------------------------------------------------------
# ChaosPlan semantics
# --------------------------------------------------------------------------

def test_chaos_plan_random_deterministic():
    a = ChaosPlan.random(seed=3, n_events=6, max_round=10, num_shards=4)
    b = ChaosPlan.random(seed=3, n_events=6, max_round=10, num_shards=4)
    assert a.events == b.events
    c = ChaosPlan.random(seed=4, n_events=6, max_round=10, num_shards=4)
    assert a.events != c.events
    assert all(1 <= e.round <= 10 and 0 <= e.shard < 4 for e in a.events)


def test_chaos_events_fire_exactly_once():
    plan = ChaosPlan(events=(ChaosEvent(round=2, kind="drop_inbox",
                                        shard=0),))
    evs = plan.events_at(2)
    assert len(evs) == 1
    plan.mark_fired(evs[0])
    assert plan.events_at(2) == []     # a replayed round does not re-fire
    plan.reset()
    assert len(plan.events_at(2)) == 1


# --------------------------------------------------------------------------
# shard-pool shrink (tentpole part 3)
# --------------------------------------------------------------------------

def test_shrink_partition_equals_independent_build():
    g, part, _ = _case(shards=4)
    new_part, new_cfg = shrink_partition(g, part.cfg, 3)
    indep = build_partition(
        g, PartitionConfig(num_shards=3, rpvo_max=part.cfg.rpvo_max,
                           seed=part.cfg.seed,
                           indegree_cutoff=part.cfg.indegree_cutoff))
    assert new_cfg.num_shards == 3
    for f in ("slot_vertex", "slot_is_root", "edge_src_root_flat",
              "edge_dst_flat", "edge_mask", "edge_w", "root_flat",
              "num_replicas", "sibling_flat", "sibling_mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(new_part, f)),
            np.asarray(getattr(indep, f)), err_msg=f)


def test_shrink_on_death_reconverges_to_oracle():
    g, part, root = _case(shards=4)
    init = _sssp_init(part, root)
    want, _ = engine.run_stacked(actions.SSSP, part, init,
                                    engine.EngineConfig())
    want_vv = engine.vertex_values(part, want)
    chaos = ChaosPlan(events=(
        ChaosEvent(round=3, kind="kill_shard", shard=2),))
    task = StackedTask(actions.SSSP, part, init, graph=g)
    got, stats, report = run_resilient(
        task, chaos=chaos, policy=RecoveryPolicy(on_dead="shrink"))
    assert report.status == "recovered"
    assert any(f.action == "shrink" for f in report.faults)
    assert task.part.S == 3            # pool shrank by the dead shard
    got_vv = engine.vertex_values(task.part, got)
    np.testing.assert_array_equal(got_vv, want_vv)


def test_migrate_values_consistent_view():
    g, part, root = _case(shards=4)
    init = _sssp_init(part, root)
    done, _ = engine.run_stacked(actions.SSSP, part, init,
                                    engine.EngineConfig())
    new_part, _ = shrink_partition(g, part.cfg, 3)
    mig = migrate_values(part, done, new_part, actions.SSSP)
    sv = np.asarray(new_part.slot_vertex)
    vv = engine.vertex_values(part, done)
    # every valid replica slot holds its vertex's old root value
    np.testing.assert_array_equal(mig[sv >= 0], vv[sv[sv >= 0]])
    assert (mig[sv < 0] == np.float32(np.inf)).all()


def test_shard_pool_delay_inside_window_never_dies():
    pool = ShardPool(4, window=3)
    pool.heartbeat_all(0)
    for r in range(1, 8):
        pool.heartbeat_all(r, except_shards=(2,) if r in (3, 4) else ())
        assert pool.tick(r) == []      # 2 missed heartbeats < window
    assert pool.alive() == [0, 1, 2, 3]


# --------------------------------------------------------------------------
# recovered rounds still satisfy the planner-mirror/with_debug harness
# --------------------------------------------------------------------------

@pytest.mark.parametrize("grid_mode", ["dense", "worklist"])
def test_records_after_recovery_match_mirrors(grid_mode):
    from test_obs import _assert_record_exact
    g, part, root = _case()
    cfg = engine.EngineConfig(use_pallas=True, grid_mode=grid_mode)
    init = _sssp_init(part, root)
    want, _ = engine.run_stacked(actions.SSSP, part, init, cfg)
    chaos = ChaosPlan(events=(
        ChaosEvent(round=3, kind="corrupt_tile", shard=1),))
    with obs.recording(keep_frontiers=True) as rec:
        got, _, report = run_resilient(
            StackedTask(actions.SSSP, part, init, cfg), chaos=chaos)
    assert report.status == "recovered"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every committed round record — including the replayed ones — is
    # internally consistent with the host mirror AND kernel counters
    _assert_record_exact(part, cfg, rec, runs={"sssp"})


# --------------------------------------------------------------------------
# sharded layout over real collectives (8 host devices, subprocess)
# --------------------------------------------------------------------------

CHILD_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import actions, engine
    from repro.core.partition import PartitionConfig, build_partition
    from repro.core.resilient import ShardedTask, run_resilient
    from repro.graph import generators
    from repro.runtime.chaos import ChaosEvent, ChaosPlan

    assert len(jax.devices()) == 8
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    g = generators.rmat(8, edge_factor=5, seed=4).with_random_weights(seed=4)
    part = build_partition(g, PartitionConfig(num_shards=8, rpvo_max=2))
    root = int(np.argsort(-g.out_degrees())[0])
    init = engine.init_values(part, actions.SSSP, {root: 0.0})

    clean, cstats, creport = run_resilient(
        ShardedTask(actions.SSSP, part, init, mesh))
    assert creport.status == "ok"

    for kind, rnd, shard in (("corrupt_tile", 3, 5), ("kill_shard", 4, 2),
                             ("drop_inbox", 3, 1)):
        chaos = ChaosPlan(events=(ChaosEvent(round=rnd, kind=kind,
                                             shard=shard),))
        got, stats, report = run_resilient(
            ShardedTask(actions.SSSP, part, init, mesh), chaos=chaos)
        assert report.status == "recovered", (kind, report.status)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(clean))
        assert stats.messages == cstats.messages, kind
        assert stats.iterations == cstats.iterations, kind
    print("RESILIENT_SHARDED_OK")
""")


def test_sharded_chaos_differential_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CHILD_SHARDED], env=env,
        capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, \
        f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "RESILIENT_SHARDED_OK" in out.stdout


# --------------------------------------------------------------------------
# serving: kill-and-restore a QueryServer mid-flight
# --------------------------------------------------------------------------

def _serving_case():
    g = generators.rmat(7, edge_factor=5, seed=5).with_random_weights(
        seed=5)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=2))
    roots = [int(r) for r in np.argsort(-g.out_degrees())[:4]]
    return g, part, roots


def test_server_kill_and_restore_bit_identical(tmp_path):
    from repro.query import QueryServer
    from repro.serve.admission import QueryStatus, ServeConfig

    g, part, roots = _serving_case()

    def submit_all(srv):
        return [srv.submit("bfs", roots[0]),
                srv.submit("sssp", roots[1]),
                srv.submit("sssp", roots[2]),
                srv.submit("bfs", roots[3])]

    # oracle: uninterrupted serving run
    oracle = QueryServer(part, n_lanes=2)
    oq = submit_all(oracle)
    ores = oracle.run()

    serve = ServeConfig(checkpoint_every=2)
    srv = QueryServer(part, n_lanes=2, serve=serve)
    qs = submit_all(srv)
    srv.attach_checkpoints(CheckpointManager(str(tmp_path)))
    for _ in range(4):                 # crash mid-flight, past a snapshot
        srv.step()
    assert srv.results.keys() != set(qs)
    del srv                            # crash

    srv2 = QueryServer.restore(part, CheckpointManager(str(tmp_path)),
                               serve=serve)
    res = srv2.run()
    assert set(res) == set(qs)
    for q, oq_ in zip(qs, oq):
        o = ores[oq_]
        r = res[q]
        np.testing.assert_array_equal(np.asarray(r.values),
                                      np.asarray(o.values))
        assert r.rounds == o.rounds
        assert r.messages == o.messages
    # queries in flight at the snapshot finish as RECOVERED, the rest OK
    statuses = {res[q].status for q in qs}
    assert QueryStatus.RECOVERED in statuses
    assert statuses <= {QueryStatus.OK, QueryStatus.RECOVERED}


def test_server_restore_without_checkpoint_raises(tmp_path):
    from repro.query import QueryServer
    _, part, _ = _serving_case()
    with pytest.raises(FileNotFoundError):
        QueryServer.restore(part, CheckpointManager(str(tmp_path)))


def test_server_degrade_in_flight():
    from repro.query import QueryServer
    from repro.serve.admission import QueryStatus
    _, part, roots = _serving_case()
    srv = QueryServer(part, n_lanes=1)
    q0 = srv.submit("sssp", roots[0])
    q1 = srv.submit("sssp", roots[1])   # queued behind the single lane
    srv.step()
    hit = srv.degrade_in_flight()
    assert set(hit) == {q0, q1}
    assert srv.results[q0].status == QueryStatus.DEGRADED
    assert srv.results[q0].values is not None          # partial values
    assert srv.results[q1].status == QueryStatus.DEGRADED
    assert srv.results[q1].values is None
    # the server stays serviceable for new traffic
    q2 = srv.submit("bfs", roots[2])
    res = srv.run()
    assert res[q2].status == QueryStatus.OK


# --------------------------------------------------------------------------
# streaming: WAL replay makes crash-mid-commit exact
# --------------------------------------------------------------------------

def _stream_case():
    g = generators.rmat(7, edge_factor=5, seed=3)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=2)
    return g, pcfg


def _stream_batch(g, seed=7, k=40):
    rng = np.random.default_rng(seed)
    ins = (rng.integers(0, g.n, k).astype(np.int32),
           rng.integers(0, g.n, k).astype(np.int32),
           (rng.random(k) + 0.1).astype(np.float32))
    dels = (np.asarray(g.src)[:10].copy(), np.asarray(g.dst)[:10].copy())
    return ins, dels


def test_streaming_wal_crash_mid_commit_exact(tmp_path):
    g, pcfg = _stream_case()
    ins, dels = _stream_batch(g)

    def make():
        sg = StreamingGraph(g, pcfg)
        sg.track("bfs", 0)
        sg.track("sssp", 1)
        sg.track("pagerank")
        return sg

    oracle = make()
    oracle.insert_edges(*ins)
    oracle.delete_edges(*dels)
    oracle.commit()

    sg = make()
    sg.insert_edges(*ins)
    sg.delete_edges(*dels)
    mgr = CheckpointManager(str(tmp_path))
    sg.save_checkpoint(mgr, blocking=True)   # WAL holds the batch
    del sg                                   # crash mid-commit

    sg2 = StreamingGraph.restore(CheckpointManager(str(tmp_path)))
    assert sg2._pending_ins and sg2._pending_del
    sg2.commit()                             # replay the WAL
    for k in oracle.tracked:
        np.testing.assert_array_equal(
            np.asarray(oracle.tracked[k]["vals"]),
            np.asarray(sg2.tracked[k]["vals"]), err_msg=str(k))


def test_streaming_checkpoint_roundtrip_post_commit(tmp_path):
    g, pcfg = _stream_case()
    ins, dels = _stream_batch(g)
    sg = StreamingGraph(g, pcfg)
    sg.track("sssp", 0)
    sg.insert_edges(*ins)
    sg.delete_edges(*dels)
    sg.commit()
    mgr = CheckpointManager(str(tmp_path))
    sg.save_checkpoint(mgr, blocking=True)
    sg2 = StreamingGraph.restore(mgr)
    assert sg2._commits == sg._commits
    assert not sg2._pending_ins and not sg2._pending_del
    np.testing.assert_array_equal(
        np.asarray(sg.tracked[("sssp", 0)]["vals"]),
        np.asarray(sg2.tracked[("sssp", 0)]["vals"]))
    # the restored instance keeps streaming: a further mutation commits
    sg.insert_edges(*_stream_batch(g, seed=9, k=8)[0])
    sg2.insert_edges(*_stream_batch(g, seed=9, k=8)[0])
    sg.commit()
    sg2.commit()
    np.testing.assert_array_equal(
        np.asarray(sg.tracked[("sssp", 0)]["vals"]),
        np.asarray(sg2.tracked[("sssp", 0)]["vals"]))


# --------------------------------------------------------------------------
# streaming staleness SLO (deferred-commit auto refresh)
# --------------------------------------------------------------------------

def test_streaming_staleness_slo_auto_refresh():
    g, pcfg = _stream_case()
    sg = StreamingGraph(g, pcfg, staleness_slo=25.0)
    sg.track("bfs", 0)
    ins, _ = _stream_batch(g, k=20)
    sg.insert_edges(*ins)              # 20 <= 25: stays buffered
    assert sg.auto_refreshes == 0 and sg._pending_ins
    more, _ = _stream_batch(g, seed=8, k=10)
    sg.insert_edges(*more)             # 30 > 25: auto-commit
    assert sg.auto_refreshes == 1
    assert not sg._pending_ins and sg.staleness() == 0.0
    # equal to an eager instance that committed the same batches
    ref = StreamingGraph(g, pcfg)
    ref.track("bfs", 0)
    ref.insert_edges(*ins)
    ref.insert_edges(*more)
    ref.commit()
    np.testing.assert_array_equal(
        np.asarray(sg.tracked[("bfs", 0)]["vals"]),
        np.asarray(ref.tracked[("bfs", 0)]["vals"]))


def test_streaming_staleness_pr_mass_metric():
    g, pcfg = _stream_case()
    sg = StreamingGraph(g, pcfg, staleness_slo=1e9,
                        staleness_metric="pr_mass")
    sg.track("pagerank")
    ins, _ = _stream_batch(g, k=15)
    sg.insert_edges(*ins)
    s = sg.staleness()
    p = np.asarray(sg.tracked[("pagerank", None)]["vals"])
    d = sg.tracked[("pagerank", None)]["damping"]
    srcs = np.unique(ins[0])
    assert s == pytest.approx(float(d * p[srcs].sum()))
    with pytest.raises(ValueError):
        StreamingGraph(g, pcfg, staleness_slo=1.0,
                       staleness_metric="nope")
