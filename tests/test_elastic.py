"""Elastic re-mesh + straggler state machine."""
import numpy as np

from repro.runtime.elastic import (
    ElasticCoordinator, StragglerMonitor, viable_mesh_shapes)


def test_viable_shapes_keep_model_axis():
    shapes = viable_mesh_shapes(n_hosts=128, devices_per_host=4,
                                model_axis=16)
    assert (2, 16, 16) in shapes
    assert all(s[2] == 16 for s in shapes)


def test_coordinator_detects_death_and_remeshes():
    c = ElasticCoordinator(n_hosts=128, devices_per_host=4, model_axis=16)
    need = False
    for step in range(8):
        for h in range(128):
            if h != 17 or step < 2:   # host 17 stops heartbeating at step 2
                c.heartbeat(h, step)
        need = need or c.tick(step)
    assert need  # re-mesh triggered once the heartbeat window expires
    assert not c.hosts[17].alive
    shape = c.current_mesh_shape()
    assert shape is not None
    # 127 hosts x 4 = 508 devices; largest viable keeps model=16 if divisible
    assert np.prod(shape) <= 127 * 4
    assert np.prod(shape) % 16 == 0


def test_coordinator_degrades_model_axis_last_resort():
    c = ElasticCoordinator(n_hosts=3, devices_per_host=1, model_axis=16)
    c.kill_host(2)
    shape = c.current_mesh_shape()
    assert shape is not None and np.prod(shape) == 2


def test_straggler_two_stage():
    m = StragglerMonitor(threshold=1.5, patience=3)
    for step in range(4):
        for h in range(8):
            m.record(h, 1.0 if h != 3 else 3.0)  # host 3 is slow
        cls = m.classify()
        if step < 2:
            assert 3 in cls["bypass"] and 3 not in cls["evict"]
    assert 3 in cls["evict"]  # escalated after patience
    assert all(h not in cls["evict"] for h in range(8) if h != 3)


def test_straggler_recovery_resets_flags():
    m = StragglerMonitor(threshold=1.5, patience=3, alpha=1.0)
    for h in range(4):
        m.record(h, 1.0 if h != 1 else 5.0)
    m.classify()
    for _ in range(3):
        for h in range(4):
            m.record(h, 1.0)  # host 1 recovers
        cls = m.classify()
    assert cls == {"bypass": [], "evict": []}
