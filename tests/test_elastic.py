"""Elastic re-mesh + straggler state machine."""
import numpy as np
import pytest

from repro.runtime.elastic import (
    ElasticCoordinator, ShardPool, StragglerMonitor, viable_mesh_shapes)


def test_viable_shapes_keep_model_axis():
    shapes = viable_mesh_shapes(n_hosts=128, devices_per_host=4,
                                model_axis=16)
    assert (2, 16, 16) in shapes
    assert all(s[2] == 16 for s in shapes)


def test_coordinator_detects_death_and_remeshes():
    c = ElasticCoordinator(n_hosts=128, devices_per_host=4, model_axis=16)
    need = False
    for step in range(8):
        for h in range(128):
            if h != 17 or step < 2:   # host 17 stops heartbeating at step 2
                c.heartbeat(h, step)
        need = need or c.tick(step)
    assert need  # re-mesh triggered once the heartbeat window expires
    assert not c.hosts[17].alive
    shape = c.current_mesh_shape()
    assert shape is not None
    # 127 hosts x 4 = 508 devices; largest viable keeps model=16 if divisible
    assert np.prod(shape) <= 127 * 4
    assert np.prod(shape) % 16 == 0


def test_coordinator_degrades_model_axis_last_resort():
    c = ElasticCoordinator(n_hosts=3, devices_per_host=1, model_axis=16)
    c.kill_host(2)
    shape = c.current_mesh_shape()
    assert shape is not None and np.prod(shape) == 2


def test_straggler_two_stage():
    m = StragglerMonitor(threshold=1.5, patience=3)
    for step in range(4):
        for h in range(8):
            m.record(h, 1.0 if h != 3 else 3.0)  # host 3 is slow
        cls = m.classify()
        if step < 2:
            assert 3 in cls["bypass"] and 3 not in cls["evict"]
    assert 3 in cls["evict"]  # escalated after patience
    assert all(h not in cls["evict"] for h in range(8) if h != 3)


def test_straggler_recovery_resets_flags():
    m = StragglerMonitor(threshold=1.5, patience=3, alpha=1.0)
    for h in range(4):
        m.record(h, 1.0 if h != 1 else 5.0)
    m.classify()
    for _ in range(3):
        for h in range(4):
            m.record(h, 1.0)  # host 1 recovers
        cls = m.classify()
    assert cls == {"bypass": [], "evict": []}


def test_viable_shapes_factorizations_exact():
    # total = 8*1 = 8, model 2 -> rest 4: (2,2,2) and (1,4,2)
    shapes = viable_mesh_shapes(n_hosts=8, devices_per_host=1,
                                model_axis=2)
    assert set(shapes) == {(2, 2, 2), (1, 4, 2)}
    # indivisible model axis -> no viable shape
    assert viable_mesh_shapes(n_hosts=3, devices_per_host=1,
                              model_axis=2) == []
    # sorted largest-device-count first, all preserve the model axis
    shapes = viable_mesh_shapes(n_hosts=64, devices_per_host=4,
                                model_axis=16)
    assert all(s[2] == 16 for s in shapes)
    sizes = [s[0] * s[1] * s[2] for s in shapes]
    assert sizes == sorted(sizes, reverse=True)


def test_declare_dead_window_boundary_exact():
    """Death fires strictly AFTER the window: a host whose last
    heartbeat was at step t dies at the first tick with
    step - t > window, not at step - t == window."""
    c = ElasticCoordinator(n_hosts=2, devices_per_host=1, model_axis=1,
                           heartbeat_window=3)
    c.heartbeat(0, 0)
    c.heartbeat(1, 0)
    for step in (1, 2, 3):
        c.heartbeat(0, step)
        assert not c.tick(step)        # host 1 silent but inside window
        assert c.hosts[1].alive
    c.heartbeat(0, 4)
    assert c.tick(4)                   # 4 - 0 > 3: declared dead
    assert not c.hosts[1].alive
    assert c.remesh_events[-1]["died"] == [1]
    # revival resets the clock: no immediate re-death
    c.revive(1, 5)
    assert not c.tick(5)
    assert c.hosts[1].alive


def test_straggler_ewma_exact_math():
    m = StragglerMonitor(alpha=0.3)
    m.record(0, 2.0)
    assert m.hosts[0].ewma_step_s == pytest.approx(2.0)  # seeded, not decayed
    m.record(0, 4.0)
    assert m.hosts[0].ewma_step_s == pytest.approx(0.3 * 4.0 + 0.7 * 2.0)
    m.record(0, 1.0)
    assert m.hosts[0].ewma_step_s == pytest.approx(
        0.3 * 1.0 + 0.7 * (0.3 * 4.0 + 0.7 * 2.0))


def test_shard_pool_heartbeat_declare_dead_and_revive():
    pool = ShardPool(4, window=2)
    pool.heartbeat_all(0)
    assert pool.tick(0) == [] and pool.alive() == [0, 1, 2, 3]
    for r in (1, 2, 3):
        pool.heartbeat_all(r, except_shards=(1, 3))
        newly = pool.tick(r)
        if r < 3:
            assert newly == []         # inside the window
        else:
            assert newly == [1, 3]     # both declared dead together
    assert pool.dead() == [1, 3]
    pool.revive(1, 4)
    assert pool.dead() == [3]
    pool.revive_all(4)
    assert pool.dead() == [] and pool.alive() == [0, 1, 2, 3]
    # tick() reports each death exactly once (newly-dead, not all-dead)
    pool2 = ShardPool(2, window=1)
    pool2.heartbeat_all(0)
    pool2.heartbeat(0, 2)
    assert pool2.tick(2) == [1]
    pool2.heartbeat(0, 3)
    assert pool2.tick(3) == []
