"""Checkpoint manager: atomicity, crc verification, async saves, gc."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 5)),
            "b": {"c": jnp.arange(7), "d": jnp.float32(seed)}}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree(0)
    cm.save(10, t)
    got = cm.restore(10, jax.tree.map(jnp.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_latest_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    assert cm.latest_step() == 4
    assert cm.all_steps() == [3, 4]  # older GC'd


def test_async_save(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree(3)
    cm.save(7, t, blocking=False)
    cm.wait()
    step, got = cm.restore_latest(jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree(1))
    # simulate a crash: a stale .tmp dir from a dead writer
    os.makedirs(str(tmp_path / "step_0000000002.tmp"))
    assert cm.latest_step() == 1
    # a new save of step 2 succeeds over the stale tmp
    cm.save(2, _tree(2))
    assert cm.latest_step() == 2


def test_corruption_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree(5)
    path = cm.save(11, t)
    # flip bytes in one leaf
    fname = os.path.join(path, "a.npy")
    arr = np.load(fname)
    arr[0, 0] += 1.0
    np.save(fname, arr)
    with pytest.raises(IOError, match="corrupt"):
        cm.restore(11, t)


def test_async_write_failure_surfaces(tmp_path):
    """A failed background save must not die silently: the writer
    thread's exception re-raises on the next wait()/save()."""
    cm = CheckpointManager(str(tmp_path))
    t = _tree(1)
    cm.save(1, t, blocking=False)
    cm.wait()                              # clean write: no raise
    # point the writer at an unwritable location (a file, not a dir)
    blocked = tmp_path / "blocked"
    blocked.write_text("not a directory")
    cm.dir = str(blocked)
    cm.save(2, t, blocking=False)
    with pytest.raises(RuntimeError, match="async checkpoint"):
        cm.wait()
    # the error is consumed: the manager is usable again
    cm.dir = str(tmp_path)
    cm.save(3, t, blocking=False)
    cm.wait()
    assert cm.latest_step() == 3


def test_async_write_failure_surfaces_on_next_save(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree(2)
    blocked = tmp_path / "blocked2"
    blocked.write_text("not a directory")
    cm.dir = str(blocked)
    cm.save(1, t, blocking=False)
    cm.dir = str(tmp_path)
    with pytest.raises(RuntimeError, match="async checkpoint"):
        cm.save(2, t)                      # save() waits first


def test_meta_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    meta = {"round": 7, "run": "sssp", "nested": {"k": [1, 2]}}
    cm.save(7, _tree(7), meta=meta)
    assert cm.restore_meta(7) == meta
    cm.save(8, _tree(8))                   # no meta -> empty dict
    assert cm.restore_meta(8) == {}
