"""Overload-safe serving (ISSUE 6 tentpole + satellites).

Covers: submit-time validation (typed errors, nothing reaches a lane),
bounded-queue overload policies (block / reject / shed), priority
preemption, deadline and timeout eviction with partial values, round
budgets (including the zero-budget immediate return), the
converged-lane-vs-deadline-expiry race, per-tenant fair admission,
the root-keyed result cache with staleness bounds, fault injection
(lane failure, delayed tick) surfacing as typed statuses, the
edge case of a full queue with every lane busy, and trace parity of the
sharded delta-PPR round vs the stacked delta path (8 host devices,
subprocess).  The default-config trace parity with the unpoliced server
is pinned by tests/test_query_server.py and the 8-device parity test in
tests/test_exchange_unified.py.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.graph.graph import COOGraph
from repro.query import (
    AdmissionError, AdmissionQueue, FaultPlan, QueryServer, QueryStatus,
    QueryValidationError, ResultCache, ServeConfig,
)

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
UNREACHED = np.iinfo(np.int32).max


def _path_graph(n):
    src = np.arange(n - 1, dtype=np.int32)
    return COOGraph(n, src, (src + 1).astype(np.int32), None)


def _path_part(n=24, num_shards=4, rpvo_max=2):
    return build_partition(_path_graph(n),
                           PartitionConfig(num_shards=num_shards,
                                           rpvo_max=rpvo_max))


def _ppr_part():
    g = generators.rmat(6, edge_factor=4, seed=3)
    from repro.apps.pagerank import _pr_graph
    return g, build_partition(_pr_graph(g),
                              PartitionConfig(num_shards=4, rpvo_max=2))


class FakeClock:
    """Deterministic wall clock: now() returns ``t`` until advanced."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------- validation
def test_submit_validation_typed_errors():
    part = _path_part()
    srv = QueryServer(part, n_lanes=1, ppr_lanes=1)
    with pytest.raises(ValueError, match="unknown query kind"):
        srv.submit("pagerank-global", 0)
    with pytest.raises(QueryValidationError, match="empty sources"):
        srv.submit("bfs", [])
    with pytest.raises(QueryValidationError, match="out of range"):
        srv.submit("bfs", part.n + 7)
    with pytest.raises(QueryValidationError, match="out of range"):
        srv.submit("sssp", [0, -3])
    with pytest.raises(QueryValidationError, match="damping"):
        srv.submit("ppr", 0, damping=float("nan"))
    with pytest.raises(QueryValidationError, match="damping"):
        srv.submit("ppr", 0, damping=-0.5)
    with pytest.raises(QueryValidationError, match="damping"):
        srv.submit("ppr", 0, damping=1.0)
    with pytest.raises(QueryValidationError, match="tol"):
        srv.submit("ppr", 0, tol=float("nan"))
    with pytest.raises(QueryValidationError, match="max_rounds"):
        srv.submit("bfs", 0, max_rounds=-1)
    with pytest.raises(QueryValidationError, match="deadline_s"):
        srv.submit("bfs", 0, deadline_s=-1.0)
    with pytest.raises(QueryValidationError, match="non-finite"):
        srv.submit("sssp", {0: float("nan")})
    # nothing was admitted, queued, or resolved
    assert srv.queue == [] and srv.results == {}
    # QueryValidationError is a ValueError: legacy callers keep working
    assert issubclass(QueryValidationError, ValueError)


# ----------------------------------------------------------- queue policies
def test_reject_policy_bounded_queue_typed_rejection():
    part = _path_part()
    srv = QueryServer(part, n_lanes=1,
                      serve=ServeConfig(max_queue=1,
                                        overload_policy="reject"))
    qa = srv.submit("bfs", 0)            # fills the queue
    qb = srv.submit("bfs", 1)            # bounced, typed — no exception
    res = srv.run()
    assert res[qa].status == QueryStatus.OK
    assert res[qb].status == QueryStatus.REJECTED
    assert res[qb].values is None and res[qb].lane == -1
    assert srv.counters["submitted"] == 2
    assert srv.counters[QueryStatus.OK] == 1
    assert srv.counters[QueryStatus.REJECTED] == 1


def test_shed_policy_evicts_lowest_priority():
    part = _path_part()
    srv = QueryServer(part, n_lanes=1,
                      serve=ServeConfig(max_queue=2,
                                        overload_policy="shed"))
    q_old = srv.submit("bfs", 0, priority=0)
    q_low = srv.submit("bfs", 1, priority=0)
    q_hot = srv.submit("bfs", 2, priority=5)   # sheds q_low (newest lowest)
    q_meh = srv.submit("bfs", 3, priority=0)   # cannot outrank: shed itself
    res = srv.run()
    assert res[q_low].status == QueryStatus.SHED
    assert res[q_meh].status == QueryStatus.SHED
    assert res[q_old].status == QueryStatus.OK
    assert res[q_hot].status == QueryStatus.OK
    # the urgent one ran before the older default-priority request
    assert res[q_hot].completed_tick < res[q_old].completed_tick
    assert srv.counters[QueryStatus.SHED] == 2


def test_block_policy_drains_and_safety_valve():
    part = _path_part()
    srv = QueryServer(part, n_lanes=1,
                      serve=ServeConfig(max_queue=1,
                                        overload_policy="block"))
    qa = srv.submit("bfs", 0)
    qb = srv.submit("bfs", 1)     # spins the server until space frees
    res = srv.run()
    assert res[qa].status == res[qb].status == QueryStatus.OK
    np.testing.assert_array_equal(
        res[qb].values, reference.bfs_levels(_path_graph(part.n), 1))

    srv2 = QueryServer(part, n_lanes=1,
                       serve=ServeConfig(max_queue=1,
                                         overload_policy="block",
                                         block_max_ticks=0))
    srv2.submit("bfs", 0)
    with pytest.raises(AdmissionError):
        srv2.submit("bfs", 1)


def test_queue_full_and_every_lane_busy():
    """Satellite edge case: submit when every lane is occupied AND the
    queue is at capacity — typed rejection, counters consistent."""
    part = _path_part()
    srv = QueryServer(part, n_lanes=1,
                      serve=ServeConfig(max_queue=1,
                                        overload_policy="reject"))
    qa = srv.submit("bfs", 0)
    srv.step()                    # qa now occupies the only min lane
    assert srv.in_flight() == 1 and len(srv.queue) == 0
    qb = srv.submit("bfs", 1)     # queued
    qc = srv.submit("bfs", 2)     # lane busy AND queue full
    res = srv.run()
    assert res[qc].status == QueryStatus.REJECTED
    assert res[qa].status == res[qb].status == QueryStatus.OK
    terminal = [r.status for r in res.values()]
    assert srv.counters["submitted"] == len(terminal) == 3
    assert all(s in QueryStatus.TERMINAL for s in terminal)


# --------------------------------------------------------------- preemption
def test_priority_preemption_restarts_victim():
    part = _path_part(n=24)
    srv = QueryServer(part, n_lanes=1)
    q_long = srv.submit("bfs", 0)
    srv.step(); srv.step()                     # victim is mid-flight
    q_hot = srv.submit("bfs", part.n - 2, priority=3)
    res = srv.run()
    assert res[q_hot].status == res[q_long].status == QueryStatus.OK
    assert res[q_hot].completed_tick < res[q_long].completed_tick
    assert res[q_long].preemptions == 1
    assert srv.counters["preemptions"] == 1
    # the restarted victim still computes the right answer
    np.testing.assert_array_equal(
        res[q_long].values, reference.bfs_levels(_path_graph(part.n), 0))
    # equal priority never preempts (the trace-parity guarantee)
    srv2 = QueryServer(part, n_lanes=1)
    srv2.submit("bfs", 0)
    srv2.step()
    srv2.submit("bfs", 1, priority=0)
    srv2.run()
    assert srv2.counters["preemptions"] == 0


# ------------------------------------------------------ deadlines / budgets
def test_deadline_evicts_mid_flight_with_partial_values():
    clk = FakeClock()
    part = _path_part(n=24)
    srv = QueryServer(part, n_lanes=1, clock=clk)
    qid = srv.submit("bfs", 0, deadline_s=10.0)
    srv.step(); srv.step(); srv.step()         # a few rounds of progress
    clk.t = 100.0                              # SLO blown mid-flight
    res = srv.run()
    r = res[qid]
    assert r.status == QueryStatus.DEADLINE_EXPIRED and r.partial
    # partial values are the mid-flight snapshot: a correct BFS prefix
    want = reference.bfs_levels(_path_graph(part.n), 0)
    got = r.values
    reached = got != UNREACHED
    assert reached.any() and not reached.all()
    np.testing.assert_array_equal(got[reached], want[reached])


def test_deadline_expires_while_queued_returns_no_values():
    clk = FakeClock()
    part = _path_part()
    srv = QueryServer(part, n_lanes=1, clock=clk)
    q_long = srv.submit("bfs", 0)
    q_slo = srv.submit("bfs", 1, deadline_s=5.0)   # stuck behind q_long
    srv.step()
    clk.t = 50.0
    res = srv.run()
    assert res[q_slo].status == QueryStatus.DEADLINE_EXPIRED
    assert res[q_slo].values is None and res[q_slo].lane == -1
    assert res[q_long].status == QueryStatus.OK


class TickClock:
    """Advances one second on every reading — so a deadline can expire
    *between* the queued-expiry sweep and the lane eviction check of a
    single tick, exposing the race."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def test_converged_lane_wins_deadline_race():
    """Satellite edge case: a lane that has already converged retires OK
    even when its deadline expired by the time the eviction check runs —
    completed work is never thrown away.  A still-live lane under the
    identical schedule is evicted with the deadline status."""
    g, part = _ppr_part()
    # deadline_s=2.5: live when the queued sweep looks (t=2 < 3.5),
    # expired when the lane eviction check looks (t=4 >= 3.5)
    srv = QueryServer(part, n_lanes=1, ppr_lanes=1, clock=TickClock())
    # tol=1.0 converges at injection (seed mass is already below tol):
    # the lane is occupied-but-converged when the eviction check runs
    qid = srv.submit("ppr", 0, tol=1.0, deadline_s=2.5)
    res = srv.run()
    assert res[qid].status == QueryStatus.OK and not res[qid].partial
    assert res[qid].values is not None

    srv2 = QueryServer(part, n_lanes=1, ppr_lanes=1, clock=TickClock())
    qid2 = srv2.submit("ppr", 0, tol=1e-9, deadline_s=2.5)  # still live
    res2 = srv2.run()
    assert res2[qid2].status == QueryStatus.DEADLINE_EXPIRED
    assert res2[qid2].partial


def test_timeout_evicts_pathological_query():
    clk = FakeClock()
    part = _path_part(n=24)
    srv = QueryServer(part, n_lanes=1, clock=clk)
    qid = srv.submit("bfs", 0, timeout_s=10.0)
    srv.step(); srv.step()
    clk.t = 99.0                    # execution cap blown
    res = srv.run()
    assert res[qid].status == QueryStatus.TIMEOUT and res[qid].partial
    assert res[qid].values is not None


def test_zero_round_budget_returns_immediately():
    """Satellite edge case: max_rounds=0 resolves at submit with the
    initial values and a partial status — no lane, no tick."""
    part = _path_part()
    srv = QueryServer(part, n_lanes=1)
    qid = srv.submit("bfs", 0, max_rounds=0)
    assert qid in srv.results                 # before any step()
    r = srv.results[qid]
    assert r.status == QueryStatus.BUDGET_EXHAUSTED and r.partial
    assert r.rounds == 0 and r.lane == -1
    want = np.full(part.n, UNREACHED, np.int64)
    want[0] = 0
    np.testing.assert_array_equal(r.values, want)
    assert srv.step() is False                # nothing was ever queued


def test_round_budget_caps_rounds_with_partial_prefix():
    part = _path_part(n=24)
    srv = QueryServer(part, n_lanes=1)
    qid = srv.submit("bfs", 0, max_rounds=3)
    q_next = srv.submit("bfs", 1)             # reuses the freed lane
    res = srv.run()
    r = res[qid]
    assert r.status == QueryStatus.BUDGET_EXHAUSTED and r.partial
    assert r.rounds == 3
    want = reference.bfs_levels(_path_graph(part.n), 0)
    got = r.values
    np.testing.assert_array_equal(got[got != UNREACHED],
                                  want[got != UNREACHED])
    assert (got != UNREACHED).sum() < part.n
    assert res[q_next].status == QueryStatus.OK


# ----------------------------------------------------------- tenant fairness
def test_tenant_fair_admission_is_starvation_free():
    part = _path_part()
    srv = QueryServer(part, n_lanes=2)
    a1 = srv.submit("bfs", 0, tenant="heavy")
    a2 = srv.submit("bfs", 1, tenant="heavy")
    a3 = srv.submit("bfs", 2, tenant="heavy")
    b1 = srv.submit("bfs", 3, tenant="light")
    res = srv.run()
    # lane 0 takes heavy's first; the deficit rule hands lane 1 to light
    # ahead of heavy's older second request
    assert res[b1].admitted_tick == 0
    assert res[a1].admitted_tick == 0
    assert res[a2].admitted_tick > 0 and res[a3].admitted_tick > 0
    assert all(res[q].status == QueryStatus.OK for q in (a1, a2, a3, b1))


# ------------------------------------------------------------- result cache
def test_result_cache_hit_and_staleness_bound():
    clk = FakeClock()
    part = _path_part()
    srv = QueryServer(part, n_lanes=1, clock=clk,
                      serve=ServeConfig(cache_size=4, cache_ttl_s=30.0))
    q1 = srv.submit("bfs", 0)
    srv.run()
    clk.t = 10.0
    q2 = srv.submit("bfs", 0)                 # fresh: served from cache
    assert q2 in srv.results                  # resolved at submit
    r2 = srv.results[q2]
    assert r2.cached and r2.status == QueryStatus.OK and r2.rounds == 0
    np.testing.assert_array_equal(r2.values, srv.results[q1].values)
    assert srv.counters["cache_hits"] == 1
    clk.t = 100.0                             # past the staleness bound
    q3 = srv.submit("bfs", 0)
    assert q3 not in srv.results              # stale: recomputed on a lane
    res = srv.run()
    assert not res[q3].cached
    assert srv.cache.hits == 1 and srv.cache.misses >= 2
    # permuted multi-source list hits the same canonical root key
    srv.submit("bfs", [2, 5])
    srv.run()
    q5 = srv.submit("bfs", [5, 2])
    assert srv.results[q5].cached


# ----------------------------------------------------------- fault injection
def test_fault_injection_lane_failure_is_typed():
    part = _path_part(n=24)
    plan = FaultPlan(lane_failures=((2, "min", 0),))
    srv = QueryServer(part, n_lanes=1, serve=ServeConfig(faults=plan))
    qid = srv.submit("bfs", 0)
    q_next = srv.submit("bfs", 1)     # the killed lane is reusable
    res = srv.run()
    assert res[qid].status == QueryStatus.FAILED
    assert res[qid].values is None
    assert res[q_next].status == QueryStatus.OK
    assert srv.counters["injected_lane_failures"] == 1


def test_fault_injection_delayed_tick_fires_timeout():
    clk = FakeClock()
    part = _path_part(n=24)
    plan = FaultPlan(tick_delays=((2, 60.0),))
    srv = QueryServer(part, n_lanes=1, clock=clk,
                      serve=ServeConfig(faults=plan))
    qid = srv.submit("bfs", 0, timeout_s=30.0)
    res = srv.run()
    assert res[qid].status == QueryStatus.TIMEOUT and res[qid].partial
    assert srv.counters["injected_delays"] == 1


# ------------------------------------------------- admission-layer unit tests
def test_admission_queue_policies_and_order():
    q = AdmissionQueue(max_queue=2, policy="shed")

    class Item:
        def __init__(self, name):
            self.name = name
    a, b, hot, cold = Item("a"), Item("b"), Item("hot"), Item("cold")
    assert q.offer(a, 0, "t")[0] == "admitted"
    assert q.offer(b, 0, "t")[0] == "admitted"
    decision, victim = q.offer(hot, 9, "t")
    assert decision == "admitted" and victim is b      # newest lowest out
    assert q.offer(cold, 0, "t") == ("shed_incoming", None)
    # priority-first dequeue; FIFO among equals
    assert q.take().item is hot
    assert q.take().item is a
    assert q.take() is None

    q2 = AdmissionQueue(max_queue=1, policy="block")
    q2.offer(a, 0, "t")
    assert q2.offer(b, 0, "t") == ("blocked", None)
    q3 = AdmissionQueue(max_queue=1, policy="reject")
    q3.offer(a, 0, "t")
    assert q3.offer(b, 0, "t") == ("rejected", None)
    with pytest.raises(ValueError, match="overload_policy"):
        ServeConfig(overload_policy="panic")
    with pytest.raises(ValueError, match="max_queue"):
        ServeConfig(max_queue=0)


def test_admission_queue_tenant_deficit_order():
    q = AdmissionQueue()

    class Item:
        def __init__(self, tenant):
            self.tenant = tenant
    h1, h2, l1 = Item("heavy"), Item("heavy"), Item("light")
    q.offer(h1, 0, "heavy")
    q.offer(h2, 0, "heavy")
    q.offer(l1, 0, "light")
    # heavy already holds a lane: light is served first despite arriving
    # last; with no lanes held the order is pure FIFO
    assert q.peek(in_flight={"heavy": 1}).item is l1
    assert q.peek(in_flight={}).item is h1
    # a heavier weight absorbs more in-flight before yielding
    q.tenant_weights = {"heavy": 4.0}
    assert q.peek(in_flight={"heavy": 1, "light": 1}).item is h1


def test_result_cache_lru_and_ttl():
    c = ResultCache(size=2, ttl_s=10.0)
    c.put("a", 1, now=0.0)
    c.put("b", 2, now=0.0)
    assert c.get("a", now=5.0) == 1            # refreshes LRU position
    c.put("c", 3, now=5.0)                     # evicts b (least recent)
    assert c.get("b", now=5.0) is None
    assert c.get("a", now=20.0) is None        # stale, never served
    assert c.get("c", now=6.0) == 3
    assert c.hits == 2 and c.misses == 2


# ------------------------------------------------- round-budget plumbing
def test_lane_budget_freezes_lane_inside_traced_round():
    """The exchange-level lane_mask plumbing: a budget-exhausted lane
    freezes inside the traced fixpoint (values carried through, no
    further rounds) while unbudgeted lanes run to convergence."""
    from repro.core import engine as eng
    from repro.query.lanes import (
        decode_min_values, init_lane_values, run_stacked_lanes,
    )
    n = 20
    part = _path_part(n=n)
    init, unitw = init_lane_values(part, [("bfs", 0), ("bfs", 0)])
    val, stats = run_stacked_lanes(part, init, unitw,
                                   lane_budget=[4, 10_000])
    _, stats_ref = run_stacked_lanes(part, init, unitw)
    assert int(stats.rounds[0]) == 4          # frozen exactly at budget
    # the unbudgeted lane converges exactly as without any budgets
    assert int(stats.rounds[1]) == int(stats_ref.rounds[1])
    want = reference.bfs_levels(_path_graph(n), 0)
    lane0 = decode_min_values(eng.vertex_values(part, val[:, :, 0]), "bfs")
    lane1 = decode_min_values(eng.vertex_values(part, val[:, :, 1]), "bfs")
    np.testing.assert_array_equal(lane1, want)          # unaffected lane
    reached = lane0 != UNREACHED
    np.testing.assert_array_equal(lane0[reached], want[reached])
    assert reached.sum() == 5                 # levels 0..4 only
    # zero budget: initial values out, zero rounds, zero messages
    val0, stats0 = run_stacked_lanes(part, init, unitw, lane_budget=0)
    np.testing.assert_array_equal(np.asarray(val0), np.asarray(init))
    assert int(stats0.rounds.sum()) == 0
    assert int(stats0.messages.sum()) == 0


# -------------------------------------------- sharded delta-PPR trace parity
CHILD_DELTA = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core import engine
    from repro.core.partition import PartitionConfig, build_partition
    from repro.graph import generators
    from repro.query import lanes as L

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    g = generators.rmat(7, edge_factor=4, seed=11)
    from repro.apps.pagerank import _pr_graph
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=8, rpvo_max=4))
    deg = np.argsort(-g.out_degrees())
    seeds = [int(deg[0]), int(deg[5])]
    dampings = np.asarray([0.85, 0.6], np.float32)
    tols = np.asarray([1e-7, 1e-7], np.float32)
    base = jnp.asarray(L.ppr_base_table(part, seeds, dampings))
    for exch in ("dense", "compact"):
        cfg = engine.EngineConfig(exchange=exch)
        arrays = engine.DeviceArrays.from_partition(part)
        st_round = L.make_ppr_delta_round(part, cfg, arrays=arrays)
        sh_round, sharding = L.make_sharded_ppr_delta_round(
            part.S, part.R_max, mesh, ("data", "model"), cfg)
        arr_spec = NamedSharding(mesh, P(("data", "model")))
        arrays_sh = jax.tree.map(
            lambda x: jax.device_put(x, arr_spec), arrays)
        r_st = d_st = base
        r_sh = d_sh = jax.device_put(base, sharding)
        dmp, tol = jnp.asarray(dampings), jnp.asarray(tols)
        for rnd in range(6):
            r_st, d_st, c_st, n_st = st_round(r_st, d_st, dmp, tol)
            r_sh, d_sh, c_sh, n_sh = sh_round(arrays_sh, r_sh, d_sh,
                                              dmp, tol)
            np.testing.assert_allclose(np.asarray(r_sh), np.asarray(r_st),
                                       rtol=1e-5, atol=1e-9)
            np.testing.assert_allclose(np.asarray(d_sh), np.asarray(d_st),
                                       rtol=1e-5, atol=1e-9)
            np.testing.assert_array_equal(np.asarray(n_sh)[0],
                                          np.asarray(n_st))
            r_sh = jax.device_put(np.asarray(r_sh), sharding)
            d_sh = jax.device_put(np.asarray(d_sh), sharding)
    print("PPR_DELTA_SHARDED_OK")
""")


def test_sharded_ppr_delta_round_trace_parity_subprocess():
    """The sharded delta-PPR round replays the stacked delta trace
    round-for-round (ranks, residuals, message counts) under real
    8-device collectives — dense and compact exchange."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CHILD_DELTA], env=env, capture_output=True,
        text=True, timeout=420)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "PPR_DELTA_SHARDED_OK" in out.stdout
