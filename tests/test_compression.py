"""int8 gradient compression: bounded error, error-feedback accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.sharding.compression import (
    BLOCK, _dequantize, _quantize, compress_decompress, init_residuals)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-6, 1e4))
def test_quantization_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, scale, size=500).astype(np.float32))
    q, s = _quantize(g)
    deq = _dequantize(q, s, g.shape, g.size)
    # per-block max error <= scale/2 (half a quantization step)
    err = np.abs(np.asarray(deq - g))
    step = np.repeat(np.asarray(s)[:, 0], BLOCK)[: g.size]
    assert (err <= step / 2 + 1e-12).all()


def test_error_feedback_preserves_sum():
    """With feedback, the *accumulated* compressed signal converges to the
    accumulated true signal (residual stays bounded)."""
    g = {"w": jnp.full((300,), 0.001, jnp.float32)}  # tiny constant grad
    res = init_residuals(g)
    total = np.zeros(300, np.float32)
    for _ in range(50):
        out, res = compress_decompress(g, res)
        total += np.asarray(out["w"])
    np.testing.assert_allclose(total, 0.05, rtol=0.05)
    assert np.abs(np.asarray(res["w"])).max() <= 0.001  # bounded residual


def test_no_feedback_mode():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=100),
                          jnp.float32)}
    out, res = compress_decompress(g, None)
    assert res is None
    assert out["w"].shape == (100,)
