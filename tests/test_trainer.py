"""Trainer: loss decreases, checkpoint/restart resumes exactly, gradient
compression trains, failure injection exercises the restore path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.lm.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.lm.models.model import Model
from repro.lm.train.optimizer import AdamW
from repro.lm.train.trainer import SimulatedFailure, Trainer, TrainerConfig


def _small_setup(tmp_path, steps=30, compress=False, ckpt_every=10):
    cfg = dataclasses.replace(
        get_config("phi3-medium-14b").reduced(), vocab=128)
    model = Model(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=0)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path), log_every=5,
                         async_ckpt=False, compress_grads=compress)
    return Trainer(model, AdamW(lr=1e-3, weight_decay=0.0), pipe, tcfg)


def test_loss_decreases(tmp_path):
    tr = _small_setup(tmp_path / "a", steps=30)
    tr.run()
    first = tr.history[0]["ce"]
    last = tr.history[-1]["ce"]
    assert last < first - 0.1, tr.history


def test_restart_resumes_exactly(tmp_path):
    # run 1: train 20 steps, checkpointing every 10
    tr1 = _small_setup(tmp_path / "b", steps=20, ckpt_every=10)
    final1 = tr1.run()

    # run 2: same config; dies at step 15, restarted, resumes from 10
    tr2 = _small_setup(tmp_path / "c", steps=20, ckpt_every=10)

    class Boom(Exception):
        pass

    def bomb(step):
        if step == 15 and not getattr(bomb, "fired", False):
            bomb.fired = True
            raise Boom()

    with pytest.raises(Boom):
        tr2.run(failure_hook=bomb)
    assert tr2.ckpt.latest_step() == 10
    final2 = tr2.run()  # auto-resumes from step 10

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6),
        final1.params, final2.params)


def test_gradient_compression_trains(tmp_path):
    tr = _small_setup(tmp_path / "d", steps=30, compress=True)
    tr.run()
    assert tr.history[-1]["ce"] < tr.history[0]["ce"] - 0.1


def test_compression_error_feedback_bounds_drift(tmp_path):
    """int8+feedback stays close to the uncompressed trajectory."""
    tr_ref = _small_setup(tmp_path / "e", steps=15)
    ref = tr_ref.run()
    tr_c = _small_setup(tmp_path / "f", steps=15, compress=True)
    comp = tr_c.run()
    # same data/seed => trajectories comparable; allow quantization drift
    ref_l = tr_ref.history[-1]["ce"]
    comp_l = tr_c.history[-1]["ce"]
    assert abs(ref_l - comp_l) < 0.5
