"""Cycle-level AM-CCA simulator + analytic cost model checks."""
import numpy as np
import pytest

from repro.core.amcca_sim import AmccaSim
from repro.core.costmodel import CostModel
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference


def _levels_from_sim(part, values):
    g = values.reshape(-1)[part.root_flat]
    out = np.where(np.isfinite(g), g, -1).astype(np.int64)
    return out


@pytest.mark.parametrize("rpvo_max", [1, 4])
@pytest.mark.parametrize("torus", [False, True])
def test_sim_bfs_matches_oracle(rpvo_max, torus):
    g = generators.ba_skewed(120, m_per=3, seed=2)
    root = int(g.src[0])
    part = build_partition(g, PartitionConfig(
        num_shards=16, rpvo_max=rpvo_max, ghost_alloc="vicinity",
        local_edge_list_size=8, torus=torus, seed=1))
    sim = AmccaSim(part, torus=torus)
    res = sim.run_min_app({root: 0.0}, weights=False)
    want = reference.bfs_levels(g, root)
    got = _levels_from_sim(part, res.values)
    finite = want != np.iinfo(np.int32).max
    np.testing.assert_array_equal(got[finite], want[finite])
    assert (got[~finite] == -1).all()
    assert res.cycles > 0 and res.actions_executed > 0


def test_sim_sssp_matches_oracle():
    g = generators.erdos_renyi(100, avg_degree=4.0, seed=3).with_random_weights(seed=3)
    root = int(g.src[0])
    part = build_partition(g, PartitionConfig(
        num_shards=16, rpvo_max=2, local_edge_list_size=8, seed=2))
    res = AmccaSim(part, torus=True).run_min_app({root: 0.0}, weights=True)
    want = reference.sssp_dijkstra(g, root)
    got = res.values.reshape(-1)[part.root_flat]
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-6)


def test_sim_lazy_diffuse_prunes():
    """Fig 6: staged diffusions get pruned when better values arrive."""
    g = generators.rmat(8, edge_factor=8, seed=5).with_random_weights(seed=5)
    root = int(g.src[0])
    part = build_partition(g, PartitionConfig(
        num_shards=16, rpvo_max=2, local_edge_list_size=16, seed=3))
    res = AmccaSim(part, torus=True).run_min_app({root: 0.0}, weights=True)
    assert res.diffusions_pruned > 0
    assert res.work_actions < res.actions_executed  # predicate pruning too


def test_torus_faster_than_mesh():
    """Fig 10: torus reduces time-to-solution, costs more energy/hop."""
    g = generators.erdos_renyi(150, avg_degree=5.0, seed=7)
    root = int(g.src[0])
    cycles = {}
    for torus in (False, True):
        part = build_partition(g, PartitionConfig(
            num_shards=64, rpvo_max=1, local_edge_list_size=8,
            torus=torus, seed=4))
        res = AmccaSim(part, torus=torus).run_min_app({root: 0.0}, weights=False)
        cycles[torus] = res.cycles
    assert cycles[True] < cycles[False]


def test_costmodel_rhizomes_cut_contention():
    """Fig 9: rhizomes flatten per-link load for skewed in-degree.

    Graph: root -> {1..n-1} -> hub, so one BFS round has ~n-1 concurrent
    messages converging on the hub — the WK/R22 hot-spot in miniature."""
    n = 600
    root, hub = 0, 1
    others = np.arange(2, n, dtype=np.int32)
    src = np.concatenate([np.full(others.size, root, np.int32), others])
    dst = np.concatenate([others, np.full(others.size, hub, np.int32)])
    from repro.graph.graph import COOGraph
    g = COOGraph(n, src, dst, None)
    trace = reference.bfs_frontier_trace(g, root)
    loads = {}
    for rmax in (1, 16):
        part = build_partition(g, PartitionConfig(
            num_shards=64, rpvo_max=rmax, local_edge_list_size=8, seed=0))
        cm = CostModel(part, torus=True)
        loads[rmax] = cm.replay(trace)
    # hub arrivals concentrate on one CC without rhizomes
    assert loads[1].cc_arrivals.max() > 4 * loads[16].cc_arrivals.max()
    assert loads[16].max_link_load < loads[1].max_link_load


def test_costmodel_strong_scaling_shape():
    """Fig 7: more compute cells => fewer (estimated) cycles, up to
    saturation, for a skewed graph with rhizomes."""
    g = generators.rmat(12, edge_factor=8, seed=11)
    root = int(np.argmax(g.out_degrees()))  # a hub: BFS reaches most vertices
    trace = reference.bfs_frontier_trace(g, root)
    assert sum(f.size for f in trace) > 1000  # non-degenerate trace
    prev = np.inf
    for shards in (16, 64, 256):
        part = build_partition(g, PartitionConfig(
            num_shards=shards, rpvo_max=8, local_edge_list_size=8, seed=6))
        res = CostModel(part, torus=True).replay(trace)
        assert res.cycles <= prev * 1.25  # allow mild non-monotonicity
        prev = min(prev, res.cycles)
