"""End-to-end behaviour tests for the whole system.

Graph side: partition -> diffusive engine -> results match oracles while
the data structure's static cost (padding, replicas, collectives) changes.
LM side (added with the model substrate): a small model trains and its
loss decreases; serving decode matches prefill logits.
"""
import numpy as np

from repro.apps import bfs, pagerank, sssp
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference


def test_graph_end_to_end_all_apps_one_partition():
    """One Rhizomatic-RPVO partition serves BFS, SSSP and PageRank."""
    g = generators.rmat(10, edge_factor=8, seed=42).with_random_weights(seed=42)
    root = int(np.argmax(g.out_degrees()))

    lv, stats_b, part = bfs(g, root, num_shards=16, rpvo_max=8)
    np.testing.assert_array_equal(lv, reference.bfs_levels(g, root))

    di, stats_s, _ = sssp(g, root, num_shards=16, rpvo_max=8)
    np.testing.assert_allclose(di, reference.sssp_dijkstra(g, root),
                               rtol=1e-5, atol=1e-5)

    pr, _ = pagerank(g, iters=15, num_shards=16, rpvo_max=8)
    np.testing.assert_allclose(pr, reference.pagerank(g, iters=15),
                               rtol=1e-4, atol=1e-7)

    # Fig-6 flavor: monotone apps prune most delivered actions
    assert int(stats_b.work_actions) < int(stats_b.messages)


def test_rhizome_static_costs_scale_with_rpvo_max():
    """rpvo_max sweep (paper Fig 8's x-axis): replicas grow, hot-slot
    inbox shrinks, padded width stays balanced."""
    g = generators.ba_skewed(1000, m_per=5, seed=13)
    prev_inbox = np.inf
    for rmax in (1, 2, 4, 8):
        part = build_partition(g, PartitionConfig(
            num_shards=32, rpvo_max=rmax, local_edge_list_size=16))
        assert part.metrics["edge_balance"] < 2.0
        assert part.metrics["max_inbox_per_slot"] <= prev_inbox
        prev_inbox = part.metrics["max_inbox_per_slot"]
