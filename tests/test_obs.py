"""Flight recorder (ISSUE 7): metrics/trace units, obs-off parity, and
the differential round-record harness.

The two acceptance bars pinned here:

* **Obs off is free** — with no recorder installed, ``run_stacked``
  dispatches exactly as before (traced ``while_loop``, no host loop),
  and the traced round function's jaxpr is byte-identical whether or
  not a recorder exists in the process.
* **Obs on is exact** — every recorded ``RoundRecord``'s grid-cell /
  tile-DMA / DMA-byte columns equal a freshly recomputed
  ``fused_grid_cells`` host mirror AND the fused kernel's
  ``with_debug`` executed-cell / issued-DMA counters on that round's
  actual frontier, across dense/worklist × pinned/tiled.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exchange, obs
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators
from repro.kernels.fused_relax_reduce import (
    fused_grid_cells, fused_relax_reduce_pallas,
)
from repro.obs import report
from repro.serve.admission import ResultCache

TINY_BUDGET = 256   # bytes: forces the tiled path for every table


# --------------------------------------------------------------------------
# metrics registry units
# --------------------------------------------------------------------------

def test_counter_gauge_labels_snapshot_delta():
    reg = obs.MetricsRegistry()
    c = reg.counter("msgs_total", "messages")
    c.labels(run="bfs").inc(5)
    c.labels(run="bfs").inc(2)
    c.labels(run="sssp").inc()
    g = reg.gauge("frontier", "live slots")
    g.labels(run="bfs").set(42)

    before = reg.snapshot()
    assert before["msgs_total"]["series"][(("run", "bfs"),)] == 7
    assert before["msgs_total"]["series"][(("run", "sssp"),)] == 1
    assert before["frontier"]["series"][(("run", "bfs"),)] == 42

    c.labels(run="bfs").inc(3)
    g.labels(run="bfs").set(10)
    d = reg.delta(before)
    # counters subtract; gauges report current level
    assert d["msgs_total"]["series"][(("run", "bfs"),)] == 3
    assert d["frontier"]["series"][(("run", "bfs"),)] == 10

    with pytest.raises(ValueError):
        reg.gauge("msgs_total")     # kind collision on a name


def test_histogram_buckets_and_prometheus_exposition():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    text = reg.render_prometheus()
    assert "# TYPE lat_seconds histogram" in text
    # cumulative bucket counts: <=0.1:1, <=1:3, <=10:4, +Inf:5
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 3' in text
    assert 'lat_seconds_bucket{le="10"} 4' in text
    assert 'lat_seconds_bucket{le="+Inf"} 5' in text
    assert "lat_seconds_count 5" in text
    assert "lat_seconds_sum 56.05" in text

    reg.counter("c_total", "c").labels(app="a b").inc()
    text = reg.render_prometheus()
    assert 'c_total{app="a b"} 1' in text


# --------------------------------------------------------------------------
# tracer / chrome schema
# --------------------------------------------------------------------------

def test_trace_chrome_schema_and_deterministic_clock():
    t = [0.0]
    tracer = obs.Tracer(clock=lambda: t[0])
    with tracer.span("round", track="engine", round=1):
        t[0] = 0.25
    tracer.instant("preempt", track="requests", qid=3)
    tracer.counter("server", {"queue_depth": 4})
    tracer.complete("queued", track="requests", start=0.1, end=0.2, qid=3)

    doc = tracer.to_chrome()
    blob = json.loads(json.dumps(doc))          # JSON round-trips
    evs = blob["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"thread_name", "round", "preempt", "server", "queued"} <= names
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        assert e["ph"] in ("X", "i", "C", "M")
        if e["ph"] != "M":
            assert isinstance(e["ts"], (int, float))
    span = next(e for e in evs if e["name"] == "round")
    assert span["ts"] == 0.0 and span["dur"] == 0.25e6   # exact: fake clock
    q = next(e for e in evs if e["name"] == "queued")
    assert q["ts"] == pytest.approx(0.1e6) and q["dur"] == pytest.approx(0.1e6)
    # distinct tracks land on distinct tids, named by metadata
    tids = {e["args"]["name"]: e["tid"] for e in evs if e["ph"] == "M"}
    assert len(set(tids.values())) == len(tids) >= 3


def test_recording_installs_and_restores():
    assert obs.get_recorder() is None
    with obs.recording() as outer:
        assert obs.get_recorder() is outer
        with obs.recording() as inner:
            assert obs.get_recorder() is inner
        assert obs.get_recorder() is outer
    assert obs.get_recorder() is None


# --------------------------------------------------------------------------
# obs-off parity: disabled must be trace-identical to today's engine
# --------------------------------------------------------------------------

def _small_case(seed=3):
    g = generators.rmat(7, edge_factor=5, seed=seed) \
        .with_random_weights(seed=seed)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=2))
    root = int(np.argmax(g.out_degrees()))
    return g, part, root


def test_obs_off_dispatch_unchanged(monkeypatch):
    """No recorder -> the dense-grid fixpoint takes the traced
    while_loop, never the host-driven loop; a recorder reroutes it."""
    _, part, root = _small_case()
    cfg = engine.EngineConfig(use_pallas=True)
    init = engine.init_values(part, actions.BFS, {root: 0.0})

    calls = []
    real = engine._run_stacked_hostloop
    monkeypatch.setattr(
        engine, "_run_stacked_hostloop",
        lambda *a, **k: calls.append(1) or real(*a, **k))

    val_off, st_off = engine.run_stacked(actions.BFS, part, init, cfg)
    assert calls == []                      # traced path, as pre-obs
    with obs.recording():
        val_on, st_on = engine.run_stacked(actions.BFS, part, init, cfg)
    assert calls == [1]                     # recorder -> host loop
    np.testing.assert_array_equal(np.asarray(val_on), np.asarray(val_off))
    assert int(st_on.messages) == int(st_off.messages)
    assert int(st_on.iterations) == int(st_off.iterations)
    assert int(st_on.pruned_actions) == int(st_off.pruned_actions)


def test_obs_off_round_jaxpr_identical():
    """The traced round function's jaxpr is byte-identical with and
    without a recorder in the process — recording never touches jit."""
    _, part, root = _small_case()
    cfg = engine.EngineConfig(use_pallas=True)
    arrays = engine.DeviceArrays.from_partition(part)
    init = jnp.asarray(engine.init_values(part, actions.BFS, {root: 0.0}))
    chg0 = jnp.zeros_like(init, bool).at[0, 0].set(True)

    def jx():
        fn = lambda v, c: exchange.fixpoint_round_stacked(  # noqa: E731
            actions.BFS, arrays, cfg, part.S, part.R_max, v, c)
        return str(jax.make_jaxpr(fn)(init, chg0))

    off = jx()
    with obs.recording():
        on = jx()
    assert on == off


# --------------------------------------------------------------------------
# the differential harness: RoundRecord == host mirror == kernel debug
# --------------------------------------------------------------------------

def _kernel_args(part, gval_flat, gchg):
    return (jnp.asarray(gval_flat), jnp.asarray(gchg),
            jnp.asarray(part.edge_src_root_flat.reshape(-1)),
            jnp.asarray(part.edge_w.reshape(-1), jnp.float32),
            jnp.asarray(part.edge_mask.reshape(-1)),
            jnp.asarray(part.edge_dst_flat.reshape(-1)))


def _assert_record_exact(part, cfg, rec, runs):
    """Every kept round: the record's counters equal (a) a freshly
    recomputed fused_grid_cells mirror, (b) the kernel's with_debug
    counters on that frontier, (c) the per-shard message mirror."""
    planner = engine.launch_planner(part, cfg)
    total = part.S * part.R_max
    rng = np.random.default_rng(0)
    gval = rng.uniform(0.0, 5.0, total).astype(np.float32)
    checked = 0
    assert len(rec.rounds) == len(rec.frontiers) > 0
    for r, gchg in zip(rec.rounds, rec.frontiers):
        if r.run not in runs:
            continue
        checked += 1
        sem = {"bfs": actions.BFS, "sssp": actions.SSSP,
               "pagerank": actions.PAGERANK}[r.run.split("_")[0]]
        assert r.frontier == int(gchg.sum())
        # (c) shard mirror: partitions messages exactly
        shard = exchange.shard_message_mirror(
            part.edge_mask, part.edge_src_root_flat, gchg)
        assert r.shard_messages == [int(x) for x in shard]
        assert sum(r.shard_messages) == r.messages
        assert r.path == planner.path
        vblk = planner.vblk if planner.path == "tiled" else None
        if r.grid == "worklist":
            wl, info = engine.plan_round_worklist(
                planner, cfg, gchg, with_info=True)
            assert wl is not None
            # (a) the planner mirror of the replanned launch
            assert (r.cells, r.launched) == (info.cells, info.launched)
            assert (r.tile_dmas, r.dma_bytes) \
                == (info.tile_dmas, info.dma_bytes)
            mirror = fused_grid_cells(
                np.asarray(part.edge_dst_flat), np.asarray(part.edge_mask),
                np.asarray(part.edge_src_root_flat), gchg, total,
                vblk=vblk, grid_mode="worklist")
            assert r.cells == mirror["wl_cells"]
            if planner.path == "tiled":
                assert r.tile_dmas == mirror["wl_tile_dmas"]
            # (b) kernel-side counters
            _, dbg = fused_relax_reduce_pallas(
                *_kernel_args(part, gval, gchg), total, sem.relax_kind,
                sem.segment, worklist=wl, with_debug=True)
        else:
            mirror = fused_grid_cells(
                np.asarray(part.edge_dst_flat), np.asarray(part.edge_mask),
                np.asarray(part.edge_src_root_flat), gchg, total, vblk=vblk)
            assert r.cells == mirror["fused_live"]
            assert r.launched == mirror["total_fused"]
            if planner.path == "tiled":
                assert r.tile_dmas == mirror["fused_tile_dmas"]
                assert r.dma_bytes == mirror["dma_bytes"]
            else:
                assert (r.tile_dmas, r.dma_bytes) == (0, 0)
            _, dbg = fused_relax_reduce_pallas(
                *_kernel_args(part, gval, gchg), total, sem.relax_kind,
                sem.segment, path=planner.path, vblk=vblk, with_debug=True)
        assert int(dbg[0]) == r.cells, (r.run, r.round)
        assert int(dbg[1]) == (r.tile_dmas if planner.path == "tiled"
                               else 0), (r.run, r.round)
    assert checked > 0


@pytest.mark.parametrize("grid_mode", ["dense", "worklist", "auto"])
@pytest.mark.parametrize("budget", [None, TINY_BUDGET])
def test_round_records_equal_mirror_and_kernel_debug(grid_mode, budget):
    _, part, root = _small_case()
    cfg = engine.EngineConfig(use_pallas=True, grid_mode=grid_mode,
                              vmem_budget_bytes=budget)
    for sem in (actions.BFS, actions.SSSP):
        with obs.recording(keep_frontiers=True) as rec:
            init = engine.init_values(part, sem, {root: 0.0})
            engine.run_stacked(sem, part, init, cfg)
        _assert_record_exact(part, cfg, rec, {sem.name})


@pytest.mark.parametrize("grid_mode", ["dense", "auto"])
def test_pagerank_delta_records_equal_mirror(grid_mode):
    g = generators.rmat(7, edge_factor=5, seed=3)
    from repro.apps.pagerank import _pr_graph
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=4, rpvo_max=2))
    cfg = engine.EngineConfig(use_pallas=True, grid_mode=grid_mode)
    with obs.recording(keep_frontiers=True) as rec:
        engine.run_pagerank_delta(part, tol=3e-5, cfg=cfg, max_rounds=8)
    _assert_record_exact(part, cfg, rec, {"pagerank_delta"})


# --------------------------------------------------------------------------
# ISSUE 8: per-WINDOW records under the device-resident fixpoint loop
# --------------------------------------------------------------------------

def _window_slices(host_rounds, k):
    """Host rounds grouped into the K-round windows the device loop
    dispatches: [0:k], [k:2k], ..."""
    return [host_rounds[i:i + k] for i in range(0, len(host_rounds), k)]


@pytest.mark.parametrize("k", [1, 2, 8])
def test_device_window_records_sum_to_host_rounds(k):
    """grid_mode='device_worklist' + recorder => one RoundRecord per
    K-round dispatch window; every additive column must sum to the
    host-driven per-round records' totals, window by window, and the
    planner mirror is recomputed post-hoc from the returned frontier
    trajectory (the record's cells/tile_dmas/dma_bytes columns)."""
    _, part, root = _small_case()
    cfg_h = engine.EngineConfig(use_pallas=True)
    cfg_dev = engine.EngineConfig(use_pallas=True,
                                  grid_mode="device_worklist",
                                  device_window=k)
    for sem in (actions.BFS, actions.SSSP):
        init = engine.init_values(part, sem, {root: 0.0})
        with obs.recording(keep_frontiers=True) as rec_h:
            val_h, st_h = engine.run_stacked(sem, part, init, cfg_h)
        with obs.recording(keep_frontiers=True) as rec_d:
            val_d, st_d = engine.run_stacked(sem, part, init, cfg_dev)
        np.testing.assert_array_equal(np.asarray(val_d),
                                      np.asarray(val_h))
        host = [r for r in rec_h.rounds if r.run == sem.name]
        dev = [r for r in rec_d.rounds if r.run == sem.name]
        assert all(r.window == 0 for r in host)      # per-round records
        assert [r.window for r in dev] == \
            list(range(1, len(dev) + 1))             # 1-based windows
        assert all(r.grid == "device_worklist" for r in dev)
        wins = _window_slices(host, k)
        assert len(dev) == len(wins)
        for dr, hw in zip(dev, wins):
            # the window's cumulative round count and entering frontier
            assert dr.round == hw[-1].round
            assert dr.frontier == hw[0].frontier
            for col in ("messages", "work", "pruned", "cells",
                        "tile_dmas", "dma_bytes"):
                assert getattr(dr, col) == \
                    sum(getattr(r, col) for r in hw), (sem.name, col)
            assert dr.shard_messages == [
                sum(col) for col in zip(*(r.shard_messages for r in hw))]
        # grand totals == RunStats == host totals
        assert sum(r.messages for r in dev) == int(st_d.messages) \
            == int(st_h.messages)
        assert sum(r.work for r in dev) == int(st_d.work_actions)
        # frontier bitmaps: window w enters on the host frontier of its
        # first round (the post-hoc mirror's recompute anchor)
        for gdev, hw in zip(rec_d.frontiers, wins):
            np.testing.assert_array_equal(gdev, rec_h.frontiers[
                rec_h.rounds.index(hw[0])])


def test_device_window_pagerank_delta_sums():
    g = generators.rmat(7, edge_factor=5, seed=3)
    from repro.apps.pagerank import _pr_graph
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=4, rpvo_max=2))
    cfg_h = engine.EngineConfig(use_pallas=True)
    cfg_dev = engine.EngineConfig(use_pallas=True,
                                  grid_mode="device_worklist",
                                  device_window=3)
    with obs.recording(keep_frontiers=True) as rec_h:
        rank_h, st_h = engine.run_pagerank_delta(part, tol=3e-5,
                                                 cfg=cfg_h, max_rounds=8)
    with obs.recording(keep_frontiers=True) as rec_d:
        rank_d, st_d = engine.run_pagerank_delta(part, tol=3e-5,
                                                 cfg=cfg_dev,
                                                 max_rounds=8)
    # sum semiring: equal up to the traced loop's reassociation (min
    # semirings are bit-identical — see the fixpoint window test above)
    np.testing.assert_allclose(np.asarray(rank_d), np.asarray(rank_h),
                               rtol=1e-6, atol=1e-9)
    host = [r for r in rec_h.rounds if r.run == "pagerank_delta"]
    dev = [r for r in rec_d.rounds if r.run == "pagerank_delta"]
    assert len(dev) == -(-len(host) // 3)
    for dr, hw in zip(dev, _window_slices(host, 3)):
        for col in ("messages", "work", "pruned", "cells", "tile_dmas",
                    "dma_bytes"):
            assert getattr(dr, col) == sum(getattr(r, col) for r in hw)
    assert sum(r.messages for r in dev) == int(st_h.messages)
    assert int(st_d.messages) == int(st_h.messages)


def test_window_field_serializes():
    _, part, root = _small_case()
    cfg = engine.EngineConfig(use_pallas=True,
                              grid_mode="device_worklist",
                              device_window=2)
    with obs.recording() as rec:
        init = engine.init_values(part, actions.BFS, {root: 0.0})
        engine.run_stacked(actions.BFS, part, init, cfg)
    rounds = rec.to_session()["rounds"]
    assert rounds and all("window" in r for r in rounds)
    assert rounds[0]["window"] == 1
    assert rounds[0]["grid"] == "device_worklist"


# --------------------------------------------------------------------------
# recorder -> session -> report
# --------------------------------------------------------------------------

def test_session_roundtrip_and_report(tmp_path):
    _, part, root = _small_case()
    with obs.recording(keep_frontiers=False,
                       meta={"case": "bfs-smoke"}) as rec:
        init = engine.init_values(part, actions.BFS, {root: 0.0})
        engine.run_stacked(actions.BFS, part, init,
                           engine.EngineConfig(use_pallas=True))
    path = tmp_path / "session.json"
    rec.save(path)
    session = obs.load_session(path)
    assert session["meta"] == {"case": "bfs-smoke"}
    assert len(session["rounds"]) == len(rec.rounds) > 0
    assert all(sum(r["shard_messages"]) == r["messages"]
               for r in session["rounds"])
    names = {m["name"] for m in session["metrics"]}
    assert {"engine_rounds_total", "engine_messages_total",
            "engine_shard_message_skew"} <= names

    text = report.render(session)
    assert "engine rounds" in text
    assert "bfs" in text
    assert "shard messages" in text and "skew" in text
    assert "trace:" in text


def test_result_cache_invalidation():
    c = ResultCache(size=8)
    c.put(("bfs", (3,)), "a", now=0.0)
    c.put(("bfs", (4,)), "b", now=0.0)
    c.put(("ppr", ((3, 1.0),), 0.85, 1e-6), "c", now=0.0)
    assert c.get(("bfs", (3,)), now=0.0) == "a"
    # root 3 stales both the bfs and the seeded-ppr entry
    assert c.invalidate(3) == 2
    assert c.get(("bfs", (3,)), now=0.0) is None
    assert c.get(("bfs", (4,)), now=0.0) == "b"
    assert c.invalidate_all() == 1
    assert len(c) == 0 and c.invalidations == 3


def test_server_spans_cache_counters_and_invalidation():
    from repro.query import QueryServer
    from repro.serve.admission import ServeConfig
    g, part, root = _small_case(seed=5)
    srv = QueryServer(part, n_lanes=2,
                      cfg=engine.EngineConfig(use_pallas=False),
                      serve=ServeConfig(cache_size=8))
    with obs.recording() as rec:
        q1 = srv.submit("bfs", root)
        srv.run()
        q2 = srv.submit("bfs", root)          # cache hit
        srv.run()
        assert srv.invalidate_cache(root) == 1
        q3 = srv.submit("bfs", root)          # miss again
        srv.run()

    snap = rec.registry.snapshot()
    cache = snap["serve_cache_total"]["series"]
    assert cache[(("event", "hit"),)] == 1
    assert cache[(("event", "miss"),)] == 2
    assert cache[(("event", "invalidation"),)] == 1
    done = snap["serve_requests_total"]["series"]
    assert sum(done.values()) == 3
    assert snap["serve_ticks_total"]["series"][()] > 0

    evs = rec.tracer.events()
    runs = [e for e in evs if e["name"] == "run" and e["ph"] == "X"]
    queued = [e for e in evs if e["name"] == "queued"]
    assert len(runs) == 3 and len(queued) == 3
    qids = {e["args"]["qid"] for e in runs}
    assert qids == {q1, q2, q3}
    assert any(e["args"].get("cached") for e in runs)
    assert any(e["name"] == "tick" for e in evs)


# --------------------------------------------------------------------------
# sharded run: per-shard message skew recorded over real collectives
# --------------------------------------------------------------------------

SHARDED_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro import obs
    from repro.apps.pagerank import _pr_graph, pagerank_delta
    from repro.graph import generators
    from repro.obs import report

    assert len(jax.devices()) == 8
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

    # BA gives the heavy-tailed in-degree the skew gauge exists for
    g = generators.ba_skewed(400, m_per=4, seed=11)
    with obs.recording(meta={"case": "sharded-skew"}) as rec:
        _, stats, _ = pagerank_delta(g, tol=3e-5, num_shards=8,
                                     rpvo_max=2, mesh=mesh, max_rounds=6)
    rounds = [r for r in rec.rounds if r.run == "pagerank_delta_sharded"]
    assert len(rounds) == int(stats.iterations) > 0
    assert sum(sum(r.shard_messages) for r in rounds) \\
        == int(stats.messages)
    assert all(len(r.shard_messages) == 8 for r in rounds)
    totals = [sum(col) for col in zip(*(r.shard_messages
                                        for r in rounds))]
    skew = max(totals) / (sum(totals) / len(totals))
    assert skew >= 1.0

    snap = rec.registry.snapshot()
    gauge = snap["engine_shard_message_skew"]["series"]
    assert (("run", "pagerank_delta_sharded"),) in gauge

    text = report.render(rec.to_session())
    assert "pagerank_delta_sharded" in text
    assert "shard messages" in text and "skew" in text
    line = next(l for l in text.splitlines() if "skew(max/mean)=" in l)
    assert f"{skew:.2f}" in line
    print("SHARDED_SKEW_OK skew=%.3f" % skew)
""")


def test_sharded_skew_recorded_subprocess():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"    # see test_engine_sharded.py
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_CHILD], env=env,
        capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "SHARDED_SKEW_OK" in out.stdout
