"""Differential test harness for the VMEM-tiled fused kernel (ISSUE 4).

The tiled path DMAs ``vblk``-wide slot tiles of the HBM-resident value
table into a double-buffered VMEM scratch per grid cell; irregular
frontiers make that tiling correctness-subtle (iPregel), so every case
here is driven through all three implementations — **tiled**, **pinned**
(the classic full-table-in-VMEM launch), and the jnp oracle
``ref.fused_relax_reduce_ref`` — and min-semiring results must agree
**bit-identically** (sum semirings agree up to float reassociation of
the per-tile partials).  Coverage: skewed degree distributions, empty
frontiers, single-vertex tiles, slot counts straddling the ``vblk``
boundary, stacked + sharded engines, and lane counts Q ∈ {1, 3, 128};
hypothesis drives randomized graphs on top when available.

Also covers the budget-based path selection (``select_kernel_path``,
``REPRO_VMEM_BUDGET``) and the 128-lane-tile padding regression (a Q=5
batch padded to the full TPU lane tile is bit-identical to unpadded jnp
lanes).

ISSUE 5 extends the harness: every differential case ALSO runs the
``grid_mode='worklist'`` twins (pinned + tiled) — values must match the
oracle bit-identically for min kinds, and the worklist kernels'
``with_debug`` executed-cell / issued-DMA counters must EXACTLY equal
the ``fused_grid_cells(grid_mode='worklist')`` host mirror.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.apps import bfs, sssp, pagerank
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.kernels import fused_relax_reduce as FR
from repro.kernels.fused_relax_reduce import (
    EBLK, LANE_TILE, SBLK, fused_grid_cells, fused_relax_reduce_pallas,
    fused_relax_reduce_lanes_pallas, resolve_vmem_budget, select_kernel_path,
)
from repro.kernels.ref import (
    fused_relax_reduce_lanes_ref, fused_relax_reduce_ref,
)
from repro.query.lanes import init_lane_values, run_stacked_lanes

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

TINY_BUDGET = 256        # bytes: forces the tiled path for every table


def _assert_worklist_parity(gval, gchg, src, w, mask, ids, nseg, relax,
                            kind, vblk, want, unitw=None):
    """ISSUE-5 harness leg: the worklist twins (pinned + tiled) of this
    case agree with the oracle (bit-identical for min) and the kernel
    debug counters equal the host planner mirror exactly."""
    gchg_np = np.asarray(gchg)
    lane_width = 1
    if gchg_np.ndim == 2:
        gchg_np = gchg_np.any(axis=-1)
        lane_width = FR._lane_pad(np.asarray(gval).shape[-1],
                                  interpret=True)
    mirror = fused_grid_cells(np.asarray(ids), np.asarray(mask),
                              np.asarray(src), gchg_np, nseg, vblk=vblk,
                              lane_width=lane_width, grid_mode="worklist")
    for path, vb in (("pinned", None), ("tiled", vblk)):
        if unitw is None:
            got, dbg = fused_relax_reduce_pallas(
                gval, gchg, src, w, mask, ids, nseg, relax, kind,
                grid_mode="worklist", path=path, vblk=vb, with_debug=True)
        else:
            got, dbg = fused_relax_reduce_lanes_pallas(
                gval, gchg, unitw, src, w, mask, ids, nseg, relax, kind,
                grid_mode="worklist", path=path, vblk=vb, with_debug=True)
        if kind == "min":
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))
        else:
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-6)
        assert int(dbg[0]) == mirror["wl_cells"]
        assert int(dbg[1]) == (mirror["wl_tile_dmas"] if path == "tiled"
                               else 0)


def _skewed_case(v, e, nseg, frontier_frac, seed, q=None):
    """Random case with a Zipf-skewed source distribution (the paper's
    R22+ RMAT regime in miniature: a few hub sources own most edges, so
    tile lists are non-uniform across chunks)."""
    rng = np.random.default_rng(seed)
    shape = (v,) if q is None else (v, q)
    gval = rng.uniform(0.0, 10.0, shape).astype(np.float32)
    gchg = rng.random(shape) < frontier_frac
    ranks = rng.permutation(v)[rng.integers(0, max(v // 8, 1), e)]
    src = ranks.astype(np.int32)                          # hub-skewed
    w = rng.uniform(0.1, 2.0, e).astype(np.float32)
    mask = rng.random(e) < 0.9
    ids = np.sort(rng.integers(0, nseg, e)).astype(np.int32)
    return tuple(jnp.asarray(x) for x in (gval, gchg, src, w, mask, ids))


def _assert_all_equal(kind, tiled, pinned, want):
    if kind == "min":
        np.testing.assert_array_equal(np.asarray(pinned), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(tiled), np.asarray(pinned))
    else:
        np.testing.assert_allclose(np.asarray(pinned), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(tiled), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# kernel-level differential: tiled == pinned == ref
# --------------------------------------------------------------------------

# slot counts straddling the vblk=128 tile boundary, single-vertex
# tables, and multi-tile tables with multi-chunk edge axes
TILED_SHAPES = [
    # (v, e, nseg, vblk)
    (1, 1, 1, 128),                 # single-vertex, single tile
    (127, 300, 50, 128),            # one partial tile
    (128, 300, 50, 128),            # exactly one tile
    (129, 300, 50, 128),            # just past the boundary
    (257, 2 * EBLK + 13, SBLK + 5, 128),   # 3 tiles, 3 edge chunks
    (500, 3 * EBLK + 9, 2 * SBLK + 1, 128),
    (300, 1000, 400, 256),          # wider tile, still multi-tile
]


@pytest.mark.parametrize("relax,kind", [
    ("add_w", "min"), ("add_one", "min"), ("mul_w", "sum")])
@pytest.mark.parametrize("v,e,nseg,vblk", TILED_SHAPES)
def test_tiled_matches_pinned_and_ref(relax, kind, v, e, nseg, vblk):
    gval, gchg, src, w, mask, ids = _skewed_case(v, e, nseg, 0.4,
                                                 seed=v + e + nseg)
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, nseg,
                                  relax, kind)
    pinned = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids, nseg,
                                       relax, kind, path="pinned")
    tiled = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids, nseg,
                                      relax, kind, path="tiled", vblk=vblk)
    _assert_all_equal(kind, tiled, pinned, want)
    _assert_worklist_parity(gval, gchg, src, w, mask, ids, nseg, relax,
                            kind, vblk, want)


@pytest.mark.parametrize("frontier_frac", [0.0, 0.05, 1.0])
def test_tiled_frontier_densities(frontier_frac):
    """Empty, sparse, and full frontiers: the tile lists shrink with the
    frontier (a dead chunk fetches nothing) but never drop a live
    contribution."""
    gval, gchg, src, w, mask, ids = _skewed_case(400, 3 * EBLK + 9, 700,
                                                 frontier_frac, seed=5)
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, 700,
                                  "add_w", "min")
    tiled, dbg = fused_relax_reduce_pallas(
        gval, gchg, src, w, mask, ids, 700, "add_w", "min",
        path="tiled", vblk=128, with_debug=True)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(want))
    if frontier_frac == 0.0:
        assert np.all(np.asarray(tiled) == np.inf)
        assert int(dbg[0]) == 0 and int(dbg[1]) == 0   # no cells, no DMAs
    else:
        assert int(dbg[1]) >= int(dbg[0]) > 0          # >=1 tile per cell
    _assert_worklist_parity(gval, gchg, src, w, mask, ids, 700, "add_w",
                            "min", 128, want)


def test_tiled_unsorted_ids_still_correct():
    gval, gchg, src, w, mask, ids = _skewed_case(300, 1000, 400, 0.5,
                                                 seed=11)
    ids = jnp.asarray(np.random.default_rng(1).permutation(
        np.asarray(ids)))
    want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, 400,
                                  "add_w", "min")
    tiled = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids, 400,
                                      "add_w", "min", path="tiled",
                                      vblk=128)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(want))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(v=st.integers(1, 400), e=st.integers(1, 1400),
           nseg=st.integers(1, 600), vblk=st.sampled_from([128, 256]),
           frontier=st.sampled_from([0.0, 0.07, 0.5, 1.0]),
           seed=st.integers(0, 2**30))
    def test_tiled_differential_hypothesis(v, e, nseg, vblk, frontier,
                                           seed):
        """Randomized differential sweep: tiled == pinned == ref
        bit-identically for the min kind, on skewed-degree graphs with
        arbitrary slot counts vs the tile boundary."""
        gval, gchg, src, w, mask, ids = _skewed_case(v, e, nseg, frontier,
                                                     seed)
        want = fused_relax_reduce_ref(gval, gchg, src, w, mask, ids, nseg,
                                      "add_w", "min")
        pinned = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids,
                                           nseg, "add_w", "min",
                                           path="pinned")
        tiled = fused_relax_reduce_pallas(gval, gchg, src, w, mask, ids,
                                          nseg, "add_w", "min",
                                          path="tiled", vblk=vblk)
        np.testing.assert_array_equal(np.asarray(pinned), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(tiled), np.asarray(want))
        _assert_worklist_parity(gval, gchg, src, w, mask, ids, nseg,
                                "add_w", "min", vblk, want)


# --------------------------------------------------------------------------
# lane-batched differential: Q ∈ {1, 3, 128}, padded tail lanes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("q", [1, 3, 128])
def test_tiled_lanes_match_pinned_and_ref(q):
    # Q=128 pads to a full lane tile already; keep the graph tiny so the
    # per-lane unrolled min loop stays cheap under interpret mode
    v, e, nseg = (40, 200, 60) if q == 128 else (260, 900, 300)
    gval, gchg, src, w, mask, ids = _skewed_case(v, e, nseg, 0.4,
                                                 seed=q, q=q)
    unitw = jnp.asarray(np.arange(q) % 2, jnp.int32)
    want = fused_relax_reduce_lanes_ref(gval, gchg, unitw, src, w, mask,
                                        ids, nseg, "add_w", "min")
    pinned = fused_relax_reduce_lanes_pallas(
        gval, gchg, unitw, src, w, mask, ids, nseg, "add_w", "min",
        path="pinned")
    tiled = fused_relax_reduce_lanes_pallas(
        gval, gchg, unitw, src, w, mask, ids, nseg, "add_w", "min",
        path="tiled", vblk=128)
    np.testing.assert_array_equal(np.asarray(pinned), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(want))
    _assert_worklist_parity(gval, gchg, src, w, mask, ids, nseg, "add_w",
                            "min", 128, want, unitw=unitw)


def test_lane_padding_to_full_tile_bit_identical():
    """ISSUE-4 satellite: the lane axis padded to the full 128-lane TPU
    tile (masked tail lanes) leaves a Q=5 batch bit-identical to the
    unpadded jnp lanes — on the pinned AND the tiled path."""
    q = 5
    gval, gchg, src, w, mask, ids = _skewed_case(150, 600, 200, 0.4,
                                                 seed=77, q=q)
    unitw = jnp.asarray([1, 0, 1, 0, 0], jnp.int32)
    want, want_counts = (
        fused_relax_reduce_lanes_ref(gval, gchg, unitw, src, w, mask, ids,
                                     200, "add_w", "min"),
        (np.asarray(mask)[:, None]
         & np.asarray(gchg)[np.asarray(src)]).sum(axis=0),
    )
    for path in ("pinned", "tiled"):
        got, counts = fused_relax_reduce_lanes_pallas(
            gval, gchg, unitw, src, w, mask, ids, 200, "add_w", "min",
            path=path, vblk=128 if path == "tiled" else None,
            lane_tile=LANE_TILE, with_count=True)
        assert got.shape == (200, q)          # tail lanes sliced off
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(counts), want_counts)


def test_lane_padding_sum_semiring_close():
    """Padded tail lanes contribute the 0 identity under 'sum' too."""
    q = 5
    gval, gchg, src, w, mask, ids = _skewed_case(100, 400, 150, 0.6,
                                                 seed=9, q=q)
    unitw = jnp.zeros(q, jnp.int32)
    want = fused_relax_reduce_lanes_ref(gval, gchg, unitw, src, w, mask,
                                        ids, 150, "mul_w", "sum")
    got = fused_relax_reduce_lanes_pallas(
        gval, gchg, unitw, src, w, mask, ids, 150, "mul_w", "sum",
        path="tiled", vblk=128, lane_tile=LANE_TILE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# engine-level differential: budget-forced tiling, stacked + sharded
# --------------------------------------------------------------------------

def test_engine_budget_forces_tiled_bit_identical():
    """A partition whose slot table exceeds the configured VMEM budget
    runs the fused path via tiling, bit-identical to the pinned kernel
    and the jnp path on BFS/SSSP (the ISSUE-4 acceptance bar)."""
    g = generators.ba_skewed(260, m_per=4, seed=9).with_random_weights(
        seed=9)
    root = int(np.argmax(g.out_degrees()))
    part = build_partition(g, PartitionConfig(num_shards=8, rpvo_max=4))
    # the budget really is exceeded -> the engine's launches are tiled
    path, vblk = select_kernel_path(part.S * part.R_max, 1, TINY_BUDGET)
    assert path == "tiled" and vblk == 128

    cfg_j = engine.EngineConfig()
    cfg_p = engine.EngineConfig(use_pallas=True)
    cfg_t = engine.EngineConfig(use_pallas=True,
                                vmem_budget_bytes=TINY_BUDGET)
    cfg_w = engine.EngineConfig(use_pallas=True, grid_mode="worklist",
                                vmem_budget_bytes=TINY_BUDGET)
    for app in (bfs, sssp):
        out_j, st_j, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_j)
        out_p, st_p, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_p)
        out_t, st_t, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_t)
        out_w, st_w, _ = app(g, root, num_shards=8, rpvo_max=4, cfg=cfg_w)
        np.testing.assert_array_equal(out_t, out_j)
        np.testing.assert_array_equal(out_t, out_p)
        np.testing.assert_array_equal(out_w, out_j)
        assert int(st_t.messages) == int(st_j.messages)
        assert int(st_t.iterations) == int(st_j.iterations)
        assert int(st_w.messages) == int(st_j.messages)
        assert int(st_w.iterations) == int(st_j.iterations)
    np.testing.assert_array_equal(
        bfs(g, root, num_shards=8, rpvo_max=4, cfg=cfg_j)[0],
        reference.bfs_levels(g, root))


@pytest.mark.parametrize("exchange", ["dense", "compact"])
def test_engine_tiled_pagerank_matches_jnp(exchange):
    g = generators.rmat(8, edge_factor=6, seed=3)
    cfg_j = engine.EngineConfig(exchange=exchange)
    cfg_t = engine.EngineConfig(exchange=exchange, use_pallas=True,
                                vmem_budget_bytes=TINY_BUDGET)
    pr_j, _ = pagerank(g, iters=15, num_shards=8, rpvo_max=4, cfg=cfg_j)
    pr_t, _ = pagerank(g, iters=15, num_shards=8, rpvo_max=4, cfg=cfg_t)
    np.testing.assert_allclose(pr_t, pr_j, rtol=1e-5, atol=1e-9)


def test_engine_tiled_sharded_matches_stacked():
    from jax.sharding import Mesh
    g = generators.erdos_renyi(180, avg_degree=4.0, seed=21)
    root = int(g.src[0])
    cfg = engine.EngineConfig(use_pallas=True,
                              vmem_budget_bytes=TINY_BUDGET)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    lv_st, _, _ = bfs(g, root, num_shards=1, cfg=cfg)
    lv_sh, _, _ = bfs(g, root, num_shards=1, mesh=mesh, cfg=cfg)
    np.testing.assert_array_equal(lv_sh, lv_st)
    np.testing.assert_array_equal(lv_st, reference.bfs_levels(g, root))


@pytest.mark.parametrize("exchange", ["dense", "compact"])
def test_laned_engine_tiled_matches_jnp(exchange):
    """Mixed BFS/SSSP lane batch through the serving runner with the
    budget forced tiny: the laned tiled kernel must be bit-identical to
    the laned jnp path, dense and compact exchange alike."""
    g = generators.ba_skewed(200, m_per=3, seed=4).with_random_weights(
        seed=4)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=4))
    init, unitw = init_lane_values(
        part, [("bfs", 0), ("sssp", 5), ("bfs", [1, 7])])
    cfg_j = engine.EngineConfig(exchange=exchange)
    cfg_t = engine.EngineConfig(exchange=exchange, use_pallas=True,
                                vmem_budget_bytes=TINY_BUDGET)
    cfg_w = engine.EngineConfig(exchange=exchange, use_pallas=True,
                                grid_mode="worklist",
                                vmem_budget_bytes=TINY_BUDGET)
    val_j, st_j = run_stacked_lanes(part, init, unitw, cfg=cfg_j)
    val_t, st_t = run_stacked_lanes(part, init, unitw, cfg=cfg_t)
    val_w, st_w = run_stacked_lanes(part, init, unitw, cfg=cfg_w)
    np.testing.assert_array_equal(np.asarray(val_t), np.asarray(val_j))
    np.testing.assert_array_equal(np.asarray(st_t.messages),
                                  np.asarray(st_j.messages))
    np.testing.assert_array_equal(np.asarray(val_w), np.asarray(val_j))
    np.testing.assert_array_equal(np.asarray(st_w.messages),
                                  np.asarray(st_j.messages))


def test_laned_engine_tiled_sharded_matches_stacked():
    from jax.sharding import Mesh
    from repro.query.lanes import run_sharded_lanes
    g = generators.ba_skewed(150, m_per=3, seed=6).with_random_weights(
        seed=6)
    part = build_partition(g, PartitionConfig(num_shards=1, rpvo_max=4))
    init, unitw = init_lane_values(
        part, [("bfs", 2), ("sssp", 9), ("sssp", 0)])
    cfg = engine.EngineConfig(use_pallas=True,
                              vmem_budget_bytes=TINY_BUDGET)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    v_sh, _ = run_sharded_lanes(part, init, unitw, mesh=mesh, cfg=cfg)
    v_st, _ = run_stacked_lanes(part, init, unitw, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(v_sh), np.asarray(v_st))


# --------------------------------------------------------------------------
# budget resolution / path selection
# --------------------------------------------------------------------------

def test_select_kernel_path_budget_rules():
    # fits: pinned
    assert select_kernel_path(1000, 1, 10**7) == ("pinned", None)
    # table (128-padded) over budget: tiled, vblk shrinks with budget
    path, vblk = select_kernel_path(10_000, 1, 8192)
    assert path == "tiled" and vblk == 1024 == (8192 // (2 * 4))
    # floor: never below one 128-slot tile, even for absurd budgets
    assert select_kernel_path(10_000, 1, 1)[1] == 128
    # lanes multiply the footprint: same budget tips laned tables sooner
    assert select_kernel_path(1000, 128, 128 * 1024)[0] == "tiled"
    assert select_kernel_path(1000, 1, 128 * 1024)[0] == "pinned"
    # vblk is capped at the padded table (one tile == whole table)
    assert select_kernel_path(100, 1, 1)[1] == 128
    with pytest.raises(ValueError, match="multiple of 128"):
        select_kernel_path(1000, 1, 1, path="tiled", vblk=100)


def test_vmem_budget_env_override(monkeypatch):
    monkeypatch.delenv(FR.VMEM_BUDGET_ENV, raising=False)
    assert resolve_vmem_budget() == FR.DEFAULT_VMEM_BUDGET_BYTES
    assert resolve_vmem_budget(4096) == 4096
    monkeypatch.setenv(FR.VMEM_BUDGET_ENV, "512")
    assert resolve_vmem_budget() == 512
    assert select_kernel_path(10_000)[0] == "tiled"   # env forces tiling
    assert resolve_vmem_budget(10**7) == 10**7        # explicit arg wins
    monkeypatch.setenv(FR.VMEM_BUDGET_ENV, "")        # empty == unset
    assert resolve_vmem_budget() == FR.DEFAULT_VMEM_BUDGET_BYTES


def test_tiled_dma_mirror_scales_with_vblk():
    """dma_bytes accounting: halving vblk can only increase the fetch
    count while shrinking per-fetch bytes; totals stay consistent."""
    gval, gchg, src, w, mask, ids = _skewed_case(512, 1500, 300, 1.0,
                                                 seed=3)
    m128 = fused_grid_cells(ids, mask, src, np.asarray(gchg), 300,
                            vblk=128)
    m256 = fused_grid_cells(ids, mask, src, np.asarray(gchg), 300,
                            vblk=256)
    assert m128["fused_tile_dmas"] >= m256["fused_tile_dmas"]
    assert m128["dma_bytes"] == m128["fused_tile_dmas"] * 128 * 4
    assert m256["dma_bytes"] == m256["fused_tile_dmas"] * 256 * 4
