"""Dynamic graph mutations + incremental recompute (paper §7)."""
import numpy as np

from repro.core.dynamic import DynamicGraph
from repro.core.partition import PartitionConfig
from repro.graph import generators, reference
from repro.graph.graph import COOGraph

UNREACHED = np.iinfo(np.int32).max


def test_insert_then_incremental_bfs_matches_full():
    g = generators.erdos_renyi(300, avg_degree=3.0, seed=5)
    root = int(np.argmax(g.out_degrees()))
    dg = DynamicGraph.build(g, PartitionConfig(num_shards=8, rpvo_max=4))
    lv0, _ = dg.bfs_full(root)
    np.testing.assert_array_equal(lv0, reference.bfs_levels(g, root))

    # insert shortcut edges from reached vertices
    reached = np.nonzero(lv0 != UNREACHED)[0]
    rng = np.random.default_rng(0)
    src = rng.choice(reached, size=10)
    dst = rng.integers(0, g.n, size=10).astype(np.int32)
    seeds = dg.insert_edges(src, dst)
    lv1, stats = dg.bfs_incremental_insert(seeds)
    np.testing.assert_array_equal(
        lv1, reference.bfs_levels(dg.g, root))


def test_incremental_touches_fewer_messages_than_full():
    g = generators.rmat(11, edge_factor=8, seed=9)
    root = int(np.argmax(g.out_degrees()))
    dg = DynamicGraph.build(g, PartitionConfig(num_shards=8, rpvo_max=4))
    lv0, stats_full = dg.bfs_full(root)
    reached = np.nonzero(lv0 != UNREACHED)[0]
    seeds = dg.insert_edges([int(reached[0])], [int(reached[-1])])
    lv1, stats_inc = dg.bfs_incremental_insert(seeds)
    np.testing.assert_array_equal(lv1, reference.bfs_levels(dg.g, root))
    # incremental work is a small fraction of the from-scratch run
    assert int(stats_inc.messages) < int(stats_full.messages) // 2


def test_delete_edges_full_recompute():
    n = 12
    src = np.arange(n - 1, dtype=np.int32)
    dst = (src + 1).astype(np.int32)
    g = COOGraph(n, src, dst, None)   # path 0->1->...->11
    dg = DynamicGraph.build(g, PartitionConfig(num_shards=4, rpvo_max=1))
    lv0, _ = dg.bfs_full(0)
    assert lv0[-1] == n - 1
    dg.delete_edges([5], [6])          # cut the path
    lv1, _ = dg.bfs_full(0)
    assert lv1[5] == 5 and lv1[6] == UNREACHED
    np.testing.assert_array_equal(lv1, reference.bfs_levels(dg.g, 0))


# --------------------------------------------------------------------------
# the same mutation paths through the fused Pallas hot path (ISSUE 2):
# delete_edges regenerates the static edge arrays — the kernel's prefetch
# tables must be rebuilt consistently — and the warm-start's sparse seeded
# frontier must not be dropped by the chunk-skip bitmap
# --------------------------------------------------------------------------

def test_delete_edges_full_recompute_use_pallas():
    from repro.core import engine
    cfg = engine.EngineConfig(use_pallas=True)
    n = 14
    src = np.arange(n - 1, dtype=np.int32)
    g = COOGraph(n, src, (src + 1).astype(np.int32), None)
    dg = DynamicGraph.build(g, PartitionConfig(num_shards=4, rpvo_max=1))
    lv0, _ = dg.bfs_full(0, cfg=cfg)
    np.testing.assert_array_equal(lv0, reference.bfs_levels(dg.g, 0))
    dg.delete_edges([7], [8])
    lv1, stats = dg.bfs_full(0, cfg=cfg)
    assert lv1[7] == 7 and lv1[8] == UNREACHED
    np.testing.assert_array_equal(lv1, reference.bfs_levels(dg.g, 0))
    assert int(stats.messages) > 0


def test_delete_edges_vectorized_mask_removes_all_copies():
    """The hashed-key mask removes every copy of each (src, dst) pair —
    including duplicates — exactly like the old per-edge membership loop."""
    n = 10
    src = np.array([0, 1, 1, 2, 2, 2, 3], np.int32)
    dst = np.array([1, 2, 2, 3, 3, 4, 4], np.int32)   # dup (1,2) and (2,3)
    g = COOGraph(n, src, dst, None)
    dg = DynamicGraph.build(g, PartitionConfig(num_shards=4, rpvo_max=1))
    dg.delete_edges([1, 2], [2, 3])
    keep = [(int(s), int(d)) for s, d in zip(dg.g.src, dg.g.dst)]
    assert keep == [(0, 1), (2, 4), (3, 4)]
    # slow-path oracle: per-pair membership
    kills = {(1, 2), (2, 3)}
    want = [(int(s), int(d)) for s, d in zip(src, dst)
            if (int(s), int(d)) not in kills]
    assert keep == want


def test_delete_edges_invalidates_every_monotone_app():
    """Deletions can raise ANY monotone min-fixpoint, so delete_edges
    must drop every cached monotone app — not just bfs."""
    n = 8
    src = np.arange(n - 1, dtype=np.int32)
    g = COOGraph(n, src, (src + 1).astype(np.int32), None)
    dg = DynamicGraph.build(g, PartitionConfig(num_shards=4, rpvo_max=1))
    dg.bfs_full(0)
    dg.values["sssp"] = np.zeros(n)     # pretend a cached SSSP/CC state
    dg.values["cc"] = np.zeros(n)
    dg.values["pagerank"] = np.zeros(n)  # sum app: unaffected by the rule
    dg.delete_edges([3], [4])
    assert "bfs" not in dg.values
    assert "sssp" not in dg.values
    assert "cc" not in dg.values
    assert "pagerank" in dg.values


def test_incremental_insert_warm_start_use_pallas():
    from repro.core import engine
    cfg = engine.EngineConfig(use_pallas=True)
    g = generators.erdos_renyi(250, avg_degree=3.0, seed=5)
    root = int(np.argmax(g.out_degrees()))
    dg = DynamicGraph.build(g, PartitionConfig(num_shards=8, rpvo_max=4))
    lv0, stats_full = dg.bfs_full(root, cfg=cfg)
    np.testing.assert_array_equal(lv0, reference.bfs_levels(g, root))

    reached = np.nonzero(lv0 != UNREACHED)[0]
    rng = np.random.default_rng(1)
    src = rng.choice(reached, size=8)
    dst = rng.integers(0, g.n, size=8).astype(np.int32)
    seeds = dg.insert_edges(src, dst)
    lv1, stats_inc = dg.bfs_incremental_insert(seeds, cfg=cfg)
    np.testing.assert_array_equal(lv1, reference.bfs_levels(dg.g, root))
    # the warm start re-diffuses only the mutation sites
    assert int(stats_inc.messages) < int(stats_full.messages)
