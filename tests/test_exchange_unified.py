"""Unified lane-generic exchange layer (ISSUE 3 tentpole).

Covers the acceptance matrix: compact-vs-dense *laned* parity across min
and sum semirings (bit-identical min values, strictly fewer exchanged
entries on a skewed partition), the unlaned/laned consistency of the
shared round composition (a Q=1 lane column equals the unlaned engine
round bit-for-bit), and the sharded QueryServer — same continuous-
batching semantics as the stacked server (no head-of-line blocking on a
1-device mesh in-process; full 8-device parity on an identical request
trace in a subprocess, min and ppr pools, dense and compact exchange).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.apps import batched_queries, personalized_pagerank
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.query import QueryServer
from repro.query.lanes import init_lane_values

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _skewed_workload(seed=4):
    g = generators.rmat(8, edge_factor=4, seed=seed).with_random_weights(
        seed=seed)
    deg = np.argsort(-g.out_degrees())
    queries = [("bfs", int(deg[0])), ("sssp", int(deg[1])),
               ("bfs", int(deg[2])), ("sssp", int(deg[7]))]
    return g, queries


# --------------------------------------------------------------------------
# compact targeted exchange on the lane axis
# --------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_compact_laned_min_bit_identical_fewer_exchanged(use_pallas):
    """A mixed BFS/SSSP lane batch on the compact targeted exchange is
    bit-identical to the dense laned path, and every lane ships strictly
    fewer exchange entries on the skewed (power-law RMAT) partition —
    the §Perf message reduction, now on the lane axis."""
    g, queries = _skewed_workload()
    dense = engine.EngineConfig(use_pallas=use_pallas)
    compact = engine.EngineConfig(use_pallas=use_pallas, exchange="compact")
    res_d, st_d, part = batched_queries(g, queries, num_shards=4,
                                        rpvo_max=2, cfg=dense)
    res_c, st_c, _ = batched_queries(g, queries, part=part, cfg=compact)
    assert part.P_t < part.R_max          # the partition is actually skewed
    for a, b in zip(res_d, res_c):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(st_d.rounds),
                                  np.asarray(st_c.rounds))
    np.testing.assert_array_equal(np.asarray(st_d.messages),
                                  np.asarray(st_c.messages))
    ex_d = np.asarray(st_d.exchanged)
    ex_c = np.asarray(st_c.exchanged)
    assert (ex_c < ex_d).all()
    assert (ex_c > 0).all()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_compact_laned_ppr_matches_dense_fewer_exchanged(use_pallas):
    """Sum-semiring lanes (personalized PageRank, per-lane damping) on the
    compact exchange: same scores as the dense laned path (to float-sum
    reassociation across the exchange — the compact path sums per-source
    partials sequentially where the dense reduce is pairwise) and
    strictly fewer exchanged entries; both match the numpy oracle."""
    g, _ = _skewed_workload()
    deg = np.argsort(-g.out_degrees())
    seeds, dampings = [int(deg[0]), int(deg[2])], [0.85, 0.6]
    sc_d, st_d, part = personalized_pagerank(
        g, seeds, dampings, num_shards=4, rpvo_max=2, tol=1e-9,
        cfg=engine.EngineConfig(use_pallas=use_pallas))
    sc_c, st_c, _ = personalized_pagerank(
        g, seeds, dampings, part=part, tol=1e-9,
        cfg=engine.EngineConfig(use_pallas=use_pallas, exchange="compact"))
    np.testing.assert_allclose(sc_c, sc_d, rtol=1e-6, atol=1e-9)
    for q, (s, d) in enumerate(zip(seeds, dampings)):
        want = reference.personalized_pagerank(g, s, d, tol=1e-12)
        np.testing.assert_allclose(sc_c[:, q], want, rtol=1e-4, atol=1e-7)
    assert (np.asarray(st_c.exchanged) < np.asarray(st_d.exchanged)).all()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_compact_laned_sharded_single_device_mesh(use_pallas):
    """The compact laned exchange under shard_map (trivial mesh) equals
    the stacked compact laned run, jnp and fused."""
    from jax.sharding import Mesh
    g, queries = _skewed_workload(seed=6)
    cfg = engine.EngineConfig(exchange="compact", use_pallas=use_pallas)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    res_sh, st_sh, part = batched_queries(g, queries, num_shards=1,
                                          rpvo_max=2, mesh=mesh, cfg=cfg)
    res_st, st_st, _ = batched_queries(g, queries, part=part, cfg=cfg)
    for a, b in zip(res_sh, res_st):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(st_sh.exchanged),
                                  np.asarray(st_st.exchanged))


def test_laned_q1_round_equals_unlaned_round():
    """The unified round composition is lane-generic: a Q=1 laned round
    equals the unlaned engine round bit-for-bit, dense and compact, so
    the engine and the query runners provably share one implementation."""
    from repro import exchange
    g, _ = _skewed_workload(seed=2)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=2))
    arrays = engine.DeviceArrays.from_partition(part)
    sem = actions.SSSP
    init, _ = init_lane_values(part, [("sssp", int(g.src[0]))])
    val = jnp.asarray(init[..., 0])
    chg = sem.improved(val, jnp.full_like(val, jnp.inf)) & arrays.slot_valid
    for exch in ("dense", "compact"):
        cfg = engine.EngineConfig(exchange=exch)
        v_u, c_u = val, chg
        v_l, c_l = val[..., None], chg[..., None]
        for _ in range(3):
            v_u, c_u, m_u = exchange.fixpoint_round_stacked(
                sem, arrays, cfg, part.S, part.R_max, v_u, c_u)
            v_l, c_l, m_l = exchange.fixpoint_round_stacked(
                sem, arrays, cfg, part.S, part.R_max, v_l, c_l,
                lane_unitw=jnp.zeros((1,), jnp.int32))
            np.testing.assert_array_equal(np.asarray(v_u),
                                          np.asarray(v_l[..., 0]))
            np.testing.assert_array_equal(np.asarray(c_u),
                                          np.asarray(c_l[..., 0]))
            assert int(m_u) == int(m_l[0])


# --------------------------------------------------------------------------
# sharded QueryServer: same continuous-batching semantics as stacked
# --------------------------------------------------------------------------

@pytest.mark.parametrize("exchange_kind", ["dense", "compact"])
def test_sharded_server_no_head_of_line_blocking(exchange_kind):
    """The stacked server's no-head-of-line-blocking acceptance test,
    run against the lanes x shard_map serving loop (1-device mesh
    in-process; the 8-device run is the subprocess test below)."""
    from jax.sharding import Mesh
    from repro.graph.graph import COOGraph
    n = 40
    src = np.arange(n - 1, dtype=np.int32)
    g = COOGraph(n, src, (src + 1).astype(np.int32), None)
    part = build_partition(g, PartitionConfig(num_shards=1, rpvo_max=1))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    srv = QueryServer(part, n_lanes=2, mesh=mesh,
                      cfg=engine.EngineConfig(exchange=exchange_kind))
    q_long = srv.submit("bfs", 0)          # n-1 rounds down the path
    q_short1 = srv.submit("bfs", n - 3)    # 2 rounds
    q_short2 = srv.submit("bfs", n - 5)    # queued: both lanes busy
    results = srv.run()
    assert set(results) == {q_long, q_short1, q_short2}

    long_r, s1, s2 = results[q_long], results[q_short1], results[q_short2]
    # short2 was admitted into short1's freed lane while long was live...
    assert s2.admitted_tick > s1.completed_tick      # freed by short1
    assert s2.admitted_tick < long_r.completed_tick  # mid-flight, long live
    assert s2.lane == s1.lane and s2.lane != long_r.lane
    # ...and neither short query waited for the long one to finish
    assert s1.completed_tick < long_r.completed_tick
    assert s2.completed_tick < long_r.completed_tick

    np.testing.assert_array_equal(long_r.values, reference.bfs_levels(g, 0))
    np.testing.assert_array_equal(s1.values,
                                  reference.bfs_levels(g, n - 3))
    np.testing.assert_array_equal(s2.values,
                                  reference.bfs_levels(g, n - 5))
    assert long_r.rounds == n
    assert long_r.exchanged > 0


def test_sharded_server_mixed_kinds_single_device_mesh():
    """Mixed min + ppr requests through the sharded serving loop match
    the numpy oracles (the ppr pool's sharded counted round included)."""
    from jax.sharding import Mesh
    g = generators.rmat(7, edge_factor=5, seed=8)
    from repro.apps.pagerank import _pr_graph
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=1, rpvo_max=2))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    deg = np.argsort(-g.out_degrees())
    srv = QueryServer(part, n_lanes=2, ppr_lanes=2, mesh=mesh)
    qa = srv.submit("ppr", int(deg[0]), damping=0.85, tol=1e-9)
    qb = srv.submit("ppr", int(deg[3]), damping=0.6, tol=1e-9)
    qc = srv.submit("bfs", int(deg[1]))
    results = srv.run()
    for qid, seed, d in ((qa, int(deg[0]), 0.85), (qb, int(deg[3]), 0.6)):
        want = reference.personalized_pagerank(g, seed, d, tol=1e-12)
        np.testing.assert_allclose(results[qid].values, want,
                                   rtol=1e-4, atol=1e-7)
    np.testing.assert_array_equal(results[qc].values,
                                  reference.bfs_levels(g, int(deg[1])))


CHILD_SERVER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import engine
    from repro.core.partition import PartitionConfig, build_partition
    from repro.graph import generators
    from repro.query import QueryServer

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    g = generators.rmat(8, edge_factor=4, seed=6).with_random_weights(seed=6)
    from repro.apps.pagerank import _pr_graph
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=8, rpvo_max=4))
    deg = np.argsort(-g.out_degrees())
    trace = [("bfs", int(deg[0])), ("sssp", int(deg[1])),
             ("ppr", int(deg[2])), ("bfs", int(deg[3])),
             ("reachability", int(deg[5])), ("sssp", int(deg[8])),
             ("ppr", int(deg[9])), ("bfs", int(deg[12]))]
    for exch in ("dense", "compact"):
        cfg = engine.EngineConfig(exchange=exch)
        servers = (QueryServer(part, n_lanes=2, ppr_lanes=1, cfg=cfg),
                   QueryServer(part, n_lanes=2, ppr_lanes=1, cfg=cfg,
                               mesh=mesh))
        out = []
        for srv in servers:
            qids = [srv.submit(kind, root, tol=1e-9) for kind, root in trace]
            out.append((qids, srv.run()))
        (q_st, r_st), (q_sh, r_sh) = out
        for a, b in zip(q_st, q_sh):
            st, sh = r_st[a], r_sh[b]
            if st.kind == "ppr":
                # sum-semiring deltas reassociate across 8 real shards, so
                # the tolerance test may trip a round apart; values agree
                # to fp noise
                np.testing.assert_allclose(sh.values, st.values,
                                           rtol=1e-5, atol=1e-9)
                assert abs(sh.rounds - st.rounds) <= 2, \\
                    (st.kind, sh.rounds, st.rounds)
            else:
                # min lanes are bit-exact, so the whole serving schedule
                # (rounds, messages, admit/complete ticks) must replay
                np.testing.assert_array_equal(sh.values, st.values)
                assert sh.rounds == st.rounds, (st.kind, sh.rounds, st.rounds)
                assert sh.messages == st.messages
                assert sh.admitted_tick == st.admitted_tick
                assert sh.completed_tick == st.completed_tick
    print("SERVER_SHARDED_OK")
""")


def test_sharded_server_eight_devices_subprocess():
    """The sharded QueryServer under real 8-device collectives serves an
    identical request trace (mixed min + ppr, deeper than the lane
    count) with the same per-request values, rounds, messages, and
    admit/complete ticks as the stacked server — dense and compact."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # pin the child to CPU: with libtpu present, backend autodetect stalls
    # on (unreachable) TPU metadata; these are CPU host devices
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CHILD_SERVER], env=env, capture_output=True,
        text=True, timeout=420)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "SERVER_SHARDED_OK" in out.stdout
