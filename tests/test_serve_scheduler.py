"""Continuous batcher: slot reuse, correctness vs single-stream decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.lm.configs import get_config
from repro.lm.models.model import Model
from repro.serve.admission import ServeConfig
from repro.serve.scheduler import ContinuousBatcher, Request


def _single_stream(model, params, prompt, n_new, max_len):
    caches = model.init_cache(1, max_len)
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = model.decode_step(
            params, tok, caches, jnp.asarray(pos, jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out


def test_batched_equals_single_stream():
    cfg = get_config("phi3-medium-14b").reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
               for l in (5, 7, 4)]
    max_len = 32

    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=max_len)
    reqs = [Request(rid=i, tokens=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    assert all(r.done for r in reqs)

    for r, p in zip(reqs, prompts):
        want = _single_stream(model, params, p, 6, max_len)
        assert r.out == want, (r.rid, r.out, want)


def test_slots_are_reused():
    """3 requests through 2 slots: the freed slot takes the queued one."""
    cfg = get_config("minitron-4b").reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                    max_new=3) for i in range(3)]
    b = ContinuousBatcher(model, params, n_slots=2, max_len=24)
    for r in reqs:
        b.submit(r)
    b.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)


def test_priority_prefill_order_and_run_returns_done():
    """High-priority prompts prefill first via the shared AdmissionQueue,
    run() returns the retired requests, and obs spans record the flow."""
    cfg = get_config("minitron-4b").reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)

    def mk(rid, prio):
        return Request(rid=rid, priority=prio, max_new=2,
                       tokens=rng.integers(0, cfg.vocab, size=4)
                       .astype(np.int32))

    # 1 slot: admission order is fully observable through prefill spans
    b = ContinuousBatcher(model, params, n_slots=1, max_len=24,
                          serve=ServeConfig(max_queue=8))
    reqs = [mk(0, 0), mk(1, 5), mk(2, 1)]
    with obs.recording() as rec:
        for r in reqs:
            b.submit(r)
        done = b.run()

    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done for r in done)

    prefills = [e for e in rec.tracer.events() if e["name"] == "prefill"]
    assert [e["args"]["rid"] for e in prefills] == [1, 2, 0]  # priority order

    snap = rec.registry.snapshot()
    admitted = sum(snap["lm_admitted_total"]["series"].values())
    finished = sum(snap["lm_requests_total"]["series"].values())
    assert admitted == 3 and finished == 3
    assert len([e for e in rec.tracer.events() if e["name"] == "tick"]) \
        == b.tick
