"""Continuous batcher: slot reuse, correctness vs single-stream decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import Model
from repro.serve.scheduler import ContinuousBatcher, Request


def _single_stream(model, params, prompt, n_new, max_len):
    caches = model.init_cache(1, max_len)
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = model.decode_step(
            params, tok, caches, jnp.asarray(pos, jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out


def test_batched_equals_single_stream():
    cfg = get_config("phi3-medium-14b").reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
               for l in (5, 7, 4)]
    max_len = 32

    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=max_len)
    reqs = [Request(rid=i, tokens=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    assert all(r.done for r in reqs)

    for r, p in zip(reqs, prompts):
        want = _single_stream(model, params, p, 6, max_len)
        assert r.out == want, (r.rid, r.out, want)


def test_slots_are_reused():
    """3 requests through 2 slots: the freed slot takes the queued one."""
    cfg = get_config("minitron-4b").reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                    max_new=3) for i in range(3)]
    b = ContinuousBatcher(model, params, n_slots=2, max_len=24)
    for r in reqs:
        b.submit(r)
    b.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
