"""Pipeline parallelism: schedule correctness (== sequential stages) on a
multi-device mesh, and gradient flow through the ppermutes."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding.pipeline import pipeline_apply

    mesh = Mesh(np.array(jax.devices()[:2]).reshape(2), ("pod",))
    n_stages, n_micro, mb, d = 2, 4, 3, 8

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n_stages, d, d)) * 0.3

    def stage_fn(wp, x):
        return jnp.tanh(x @ wp)

    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

    fn = pipeline_apply(stage_fn, n_stages, n_micro, mesh)
    got = jax.jit(fn)({"w": w}["w"] if False else w, x)

    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)

    # grads flow through ppermute
    def loss(w):
        return jnp.sum(fn(w, x) ** 2)
    g = jax.grad(loss)(w)
    assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).sum()) > 0
    print("PIPELINE_OK")
""")


def test_pipeline_matches_sequential_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # pin the child to CPU: with libtpu present, backend autodetect
    # stalls on (unreachable) TPU metadata; these meshes are CPU
    # host devices by construction (xla_force_host_platform_device_count)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", CHILD], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "PIPELINE_OK" in out.stdout
