"""AND-gate LCO semantics (paper §4.1, Fig 3)."""
import operator

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.lco import AndGate, Future, and_gate_tree


def test_and_gate_fires_at_n():
    gate = AndGate(target=3, op=operator.add, identity=0.0)
    gate, fired, _ = gate.set(1.0)
    assert not fired
    gate, fired, _ = gate.set(2.0)
    assert not fired
    gate, fired, val = gate.set(3.0)
    assert fired and val == 6.0
    # reset after firing: usable again (paper: "the score AND Gate is reset")
    gate, fired, _ = gate.set(5.0)
    assert not fired and gate.count == 1


def test_and_gate_min_op():
    gate = AndGate(target=2, op=min, identity=float("inf"))
    gate, _, _ = gate.set(4.0)
    _, fired, val = gate.set(2.0)
    assert fired and val == 2.0


def test_future_write_once():
    f = Future()
    f2 = f.set(42)
    assert f2.ready and f2.value == 42
    with pytest.raises(RuntimeError):
        f2.set(43)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=40),
       st.integers(2, 5))
def test_and_gate_tree_sum(vals, fanin):
    """Hierarchical counted-trigger reduction == flat reduction (the
    hardware-signalling termination-detection analog)."""
    got, depth = and_gate_tree(np.array(vals), operator.add, 0.0, fanin=fanin)
    np.testing.assert_allclose(got, sum(vals), rtol=1e-9)
    assert depth <= int(np.ceil(np.log(max(len(vals), 2)) / np.log(fanin))) + 1


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=40))
def test_and_gate_tree_min(vals):
    got, _ = and_gate_tree(np.array(vals), min, float("inf"))
    assert got == min(vals)
