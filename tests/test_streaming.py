"""Streaming graphs: the mutation-differential suite (ISSUE 9).

Pins the streaming path's exactness: after EVERY interleaved
insert/delete batch, incrementally-maintained results equal a cold
fixpoint on the final graph — BFS/SSSP/CC bit-identical (min semirings
are order-independent over the same f32 path sums), delta-PageRank
within its residual tolerance — and the spliced partition equals a
from-scratch ``build_partition`` field for field, across the
jnp/fused × dense/worklist/device_worklist × stacked/lanes matrix
(sharded runs in a subprocess with forced host devices).  The
adaptive-rhizome split test additionally holds the planner mirror and
the kernel's ``with_debug`` counters fixed across splice vs rebuild.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.core.streaming import (StreamingGraph, invalidate_unsupported,
                                  _pr_weights)
from repro.graph import generators, reference

UNREACHED = np.iinfo(np.int32).max


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _to_levels(lv):
    out = np.full(lv.size, UNREACHED, np.int64)
    fin = np.isfinite(lv)
    out[fin] = lv[fin].astype(np.int64)
    return out


def _canon(lbl):
    m = {}
    out = np.empty(len(lbl), np.int64)
    for i, x in enumerate(lbl):
        out[i] = m.setdefault(x, len(m))
    return out


def _assert_parts_equal(got, want):
    for f in dataclasses.fields(want):
        if f.name in ("cfg", "metrics"):
            continue
        a, b = getattr(got, f.name), getattr(want, f.name)
        if isinstance(a, np.ndarray):
            assert np.array_equal(a, b), f.name
        else:
            assert a == b, f.name


def _random_batch(rng, n, k_ins, k_del, g):
    s = rng.integers(0, n, k_ins).astype(np.int32)
    d = rng.integers(0, n, k_ins).astype(np.int32)
    w = rng.integers(1, 10, k_ins).astype(np.float32)
    if k_del and g.num_edges > k_del:
        idx = rng.choice(g.num_edges, k_del, replace=False)
        return (s, d, w), (g.src[idx], g.dst[idx])
    return (s, d, w), None


def _check_all(sg, root, pr_tol):
    """Every tracked result vs a cold oracle on the CURRENT graph, and
    min apps bit-identical vs a cold engine run on the SAME partition."""
    gf = sg.g
    np.testing.assert_array_equal(
        _to_levels(sg.values("bfs", root)), reference.bfs_levels(gf, root))
    want = reference.sssp_dijkstra(gf, root)
    got = sg.values("sssp", root)
    fin = np.isfinite(want)
    assert (np.isfinite(got) == fin).all()
    np.testing.assert_array_equal(got[fin].astype(np.float32),
                                  want[fin].astype(np.float32))
    np.testing.assert_array_equal(
        _canon(sg.values("cc").tolist()),
        _canon(reference.connected_components(gf).tolist()))
    # bit-identity: cold fixpoint on the spliced partition
    part = sg.view("base").part
    init = engine.init_values(part, actions.SSSP, {root: 0.0})
    val, _ = engine.run_stacked(actions.SSSP, part, init,
                                engine.EngineConfig())
    np.testing.assert_array_equal(engine.vertex_values(part, val),
                                  sg.values("sssp", root))
    if ("pagerank", None) in sg.tracked:
        part_pr = build_partition(_pr_weights(gf), sg.pcfg)
        rank_t, _ = engine.run_pagerank_delta(
            part_pr, damping=0.85, tol=pr_tol, cfg=engine.EngineConfig())
        want_pr = engine.vertex_values(part_pr, rank_t)
        err = float(np.abs(sg.values("pagerank") - want_pr).max())
        # each vertex may keep a sub-tol residual per in-edge per run
        assert err < 200 * pr_tol, err


def _drive(sg, rng, root, batches=4, k_ins=8, k_del=4, pr_tol=1e-7):
    for b in range(batches):
        (s, d, w), dele = _random_batch(
            rng, sg.g.n, k_ins, k_del if b % 2 else 0, sg.g)
        sg.insert_edges(s, d, w)
        if dele is not None:
            sg.delete_edges(*dele)
        info = sg.commit()
        _check_all(sg, root, pr_tol)
        _assert_parts_equal(sg.view("base").part,
                            build_partition(sg.g, sg.pcfg))
        for key, ms in info.maint.items():
            assert ms.mode == "warm"
    return info


# --------------------------------------------------------------------------
# the differential matrix (satellite 1)
# --------------------------------------------------------------------------

MATRIX = [
    # (use_pallas, grid_mode, runner)  — every axis value covered
    (False, "dense", "stacked"),
    (True, "dense", "stacked"),
    (True, "worklist", "stacked"),
    (False, "dense", "lanes"),          # Q=3 laned maintenance
    (True, "device_worklist", "lanes"),
]


@pytest.mark.parametrize("use_pallas,grid_mode,runner", MATRIX)
def test_mutation_differential(use_pallas, grid_mode, runner):
    cfg = (engine.EngineConfig(use_pallas=True, grid_mode=grid_mode)
           if use_pallas else engine.EngineConfig())
    g = generators.rmat(6, edge_factor=6, seed=3).with_random_weights(seed=3)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=3,
                           local_edge_list_size=8, seed=9)
    sg = StreamingGraph(g, pcfg, cfg=cfg, runner=runner)
    root = int(g.src[0])
    sg.track("bfs", root)
    sg.track("sssp", root)
    if runner == "lanes":
        sg.track("sssp", int(g.dst[0]))   # third lane in the group run
    sg.track("cc")
    sg.track("pagerank", tol=1e-7)
    _drive(sg, np.random.default_rng(0), root)


def test_mutation_differential_q1_single_lane():
    """Q=1: a single tracked min query still goes through the laned
    group path."""
    g = generators.rmat(6, edge_factor=5, seed=4)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=2,
                           local_edge_list_size=8, seed=2)
    sg = StreamingGraph(g, pcfg, runner="lanes")
    root = int(g.src[0])
    sg.track("bfs", root)
    rng = np.random.default_rng(7)
    for _ in range(3):
        (s, d, w), dele = _random_batch(rng, g.n, 6, 3, sg.g)
        sg.insert_edges(s, d, w)
        if dele is not None:
            sg.delete_edges(*dele)
        sg.commit()
        np.testing.assert_array_equal(
            _to_levels(sg.values("bfs", root)),
            reference.bfs_levels(sg.g, root))


def test_mutation_differential_sharded():
    """The sharded runner (lanes × shard_map with real collectives),
    under forced host devices in a subprocess."""
    prog = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.partition import PartitionConfig
        from repro.core.streaming import StreamingGraph
        from repro.graph import generators, reference

        g = generators.rmat(6, edge_factor=6, seed=3)\\
            .with_random_weights(seed=3)
        mesh = Mesh(np.array(jax.devices()[:8]).reshape(8,), ("data",))
        pcfg = PartitionConfig(num_shards=8, rpvo_max=3,
                               local_edge_list_size=8, seed=9)
        sg = StreamingGraph(g, pcfg, runner="sharded", mesh=mesh,
                            axis_names=("data",))
        root = int(g.src[0])
        sg.track("bfs", root); sg.track("sssp", root)
        sg.track("pagerank", tol=1e-7)
        rng = np.random.default_rng(1)
        for batch in range(3):
            s = rng.integers(0, g.n, 6).astype(np.int32)
            d = rng.integers(0, g.n, 6).astype(np.int32)
            sg.insert_edges(s, d, rng.integers(1, 10, 6).astype(np.float32))
            if batch == 1:
                idx = rng.choice(sg.g.num_edges, 4, replace=False)
                sg.delete_edges(sg.g.src[idx], sg.g.dst[idx])
            sg.commit()
            want = reference.sssp_dijkstra(sg.g, root)
            got = sg.values("sssp", root)
            fin = np.isfinite(want)
            assert (np.isfinite(got) == fin).all()
            np.testing.assert_array_equal(
                got[fin].astype(np.float32), want[fin].astype(np.float32))
            lv = sg.values("bfs", root)
            out = np.full(g.n, np.iinfo(np.int32).max, np.int64)
            f2 = np.isfinite(lv); out[f2] = lv[f2].astype(np.int64)
            np.testing.assert_array_equal(
                out, reference.bfs_levels(sg.g, root))
        print("OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + ([os.environ["PYTHONPATH"]]
                      if os.environ.get("PYTHONPATH") else [])))
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "OK" in res.stdout


# --------------------------------------------------------------------------
# property-based schedules (hypothesis, when available)
# --------------------------------------------------------------------------

def test_hypothesis_random_schedules():
    pytest.importorskip("hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           scale=st.integers(5, 6),
           batches=st.integers(1, 3))
    def run(seed, scale, batches):
        rng = np.random.default_rng(seed)
        g = generators.rmat(scale, edge_factor=5,
                            seed=seed % 1000).with_random_weights(
                                seed=seed % 997)
        pcfg = PartitionConfig(num_shards=4, rpvo_max=3,
                               local_edge_list_size=8,
                               seed=int(rng.integers(0, 100)))
        sg = StreamingGraph(g, pcfg)
        root = int(g.src[0])
        sg.track("bfs", root)
        sg.track("sssp", root)
        sg.track("cc")
        for _ in range(batches):
            (s, d, w), dele = _random_batch(
                rng, g.n, int(rng.integers(1, 10)),
                int(rng.integers(0, 6)), sg.g)
            sg.insert_edges(s, d, w)
            if dele is not None:
                sg.delete_edges(*dele)
            sg.commit()
            _check_all(sg, root, 1e-7)
            _assert_parts_equal(sg.view("base").part,
                                build_partition(sg.g, sg.pcfg))

    run()


# --------------------------------------------------------------------------
# delete-side support invalidation is sound AND tight
# --------------------------------------------------------------------------

def test_invalidate_unsupported_exact_region():
    # path 0->1->2->3->4 plus a backup edge 0->3 (weight = old dist 3)
    from repro.graph.graph import COOGraph
    n = 5
    g0 = COOGraph(n, np.array([0, 1, 2, 3], np.int32),
                  np.array([1, 2, 3, 4], np.int32),
                  np.ones(4, np.float32))
    vals = np.array([0, 1, 2, 3, 4], np.float32)
    pinned = np.zeros(n, bool)
    pinned[0] = True
    # delete 2->3: 3 and (transitively) 4 lose support
    g1 = COOGraph(n, np.array([0, 1, 3], np.int32),
                  np.array([1, 2, 4], np.int32), np.ones(3, np.float32))
    inv = invalidate_unsupported(g1, vals, [2], [3], [1.0], pinned,
                                 unit_w=False)
    np.testing.assert_array_equal(inv, [0, 0, 0, 1, 1])
    # same deletion but an equal-cost alternate path keeps 3 (and so 4)
    g2 = COOGraph(n, np.array([0, 1, 0, 3], np.int32),
                  np.array([1, 2, 3, 4], np.int32),
                  np.array([1, 1, 3, 1], np.float32))
    inv = invalidate_unsupported(g2, vals, [2], [3], [1.0], pinned,
                                 unit_w=False)
    np.testing.assert_array_equal(inv, [0, 0, 0, 0, 0])


def test_deletes_only_relift_affected_region():
    """A delete far from most of the graph re-lifts only its cone:
    warm messages ≪ cold messages."""
    g = generators.rmat(8, edge_factor=8, seed=11)
    pcfg = PartitionConfig(num_shards=8, rpvo_max=4,
                           local_edge_list_size=8, seed=1)
    sg = StreamingGraph(g, pcfg)
    root = int(np.argmax(g.out_degrees()))
    sg.track("bfs", root)
    # cold baseline on the same engine config
    part = sg.view("base").part
    init = engine.init_values(part, actions.BFS, {root: 0.0})
    _, cold = engine.run_stacked(actions.BFS, part, init,
                                 engine.EngineConfig())
    # delete one reachable leaf-ish edge
    lv = sg.values("bfs", root)
    deep = np.isfinite(lv) & (lv >= np.nanmax(np.where(
        np.isfinite(lv), lv, np.nan)) - 1)
    cand = np.nonzero(deep[g.dst])[0]
    assert cand.size
    e = int(cand[0])
    sg.delete_edges([g.src[e]], [g.dst[e]])
    info = sg.commit()
    np.testing.assert_array_equal(
        _to_levels(sg.values("bfs", root)),
        reference.bfs_levels(sg.g, root))
    ms = info.maint[("bfs", root)]
    assert ms.messages < int(cold.messages) // 2


# --------------------------------------------------------------------------
# adaptive rhizome growth (satellite 2)
# --------------------------------------------------------------------------

def test_adaptive_split_matches_from_scratch():
    """Stream edges into one hub until its in-degree crosses the pinned
    Eq. 1 cutoff: the online split must produce (a) more replicas for
    the hub, (b) values, (c) per-round planner-mirror records and
    (d) ``with_debug`` kernel counters all exactly equal to a
    from-scratch partition of the final graph."""
    from repro import exchange, obs
    from repro.kernels.fused_relax_reduce import (
        fused_grid_cells, fused_relax_reduce_pallas)
    import jax.numpy as jnp

    g = generators.erdos_renyi(64, avg_degree=3.0, seed=6)
    hub = 7
    pcfg = PartitionConfig(num_shards=4, rpvo_max=4,
                           local_edge_list_size=8, seed=3,
                           indegree_cutoff=4)
    sg = StreamingGraph(g, pcfg)
    root = int(g.src[0])
    sg.track("bfs", root)

    def hub_replicas(part):
        return int(part.num_replicas[hub])

    r0 = hub_replicas(sg.view("base").part)
    added = 0
    rng = np.random.default_rng(2)
    while hub_replicas(sg.view("base").part) == r0:
        s = rng.integers(0, g.n, 4).astype(np.int32)
        sg.insert_edges(s, np.full(4, hub, np.int32))
        info = sg.commit()
        added += info.replicas_added
        assert added < 64, "hub never split"
    assert added >= 1
    assert hub_replicas(sg.view("base").part) > r0

    part = sg.view("base").part
    cold = build_partition(sg.g, sg.pcfg)
    _assert_parts_equal(part, cold)
    np.testing.assert_array_equal(
        _to_levels(sg.values("bfs", root)),
        reference.bfs_levels(sg.g, root))

    # post-split rounds: record stream on the spliced partition ==
    # record stream on the from-scratch partition, and each round's
    # planner mirror + kernel debug counters agree
    cfg = engine.EngineConfig(use_pallas=True, grid_mode="worklist")
    recs = {}
    for name, p in (("spliced", part), ("scratch", cold)):
        with obs.recording(keep_frontiers=True) as rec:
            init = engine.init_values(p, actions.BFS, {root: 0.0})
            engine.run_stacked(actions.BFS, p, init, cfg)
        recs[name] = rec
    a, b = recs["spliced"], recs["scratch"]
    assert len(a.rounds) == len(b.rounds) > 0
    for ra, rb in zip(a.rounds, b.rounds):
        assert (ra.messages, ra.frontier, ra.cells, ra.launched,
                ra.tile_dmas, ra.dma_bytes) \
            == (rb.messages, rb.frontier, rb.cells, rb.launched,
                rb.tile_dmas, rb.dma_bytes)
    planner = engine.launch_planner(part, cfg)
    total = part.S * part.R_max
    gval = np.random.default_rng(0).uniform(
        0.0, 5.0, total).astype(np.float32)
    for r, gchg in zip(a.rounds, a.frontiers):
        wl, info = engine.plan_round_worklist(planner, cfg, gchg,
                                              with_info=True)
        assert (r.cells, r.launched) == (info.cells, info.launched)
        _, dbg = fused_relax_reduce_pallas(
            jnp.asarray(gval), jnp.asarray(gchg),
            jnp.asarray(part.edge_src_root_flat.reshape(-1)),
            jnp.asarray(part.edge_w.reshape(-1), jnp.float32),
            jnp.asarray(part.edge_mask.reshape(-1)),
            jnp.asarray(part.edge_dst_flat.reshape(-1)),
            total, actions.BFS.relax_kind, actions.BFS.segment,
            worklist=wl, with_debug=True)
        assert int(dbg[0]) == r.cells


def test_pinned_cutoff_defaults_from_initial_graph():
    g = generators.rmat(6, edge_factor=6, seed=5)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=4,
                           local_edge_list_size=8, seed=1)
    sg = StreamingGraph(g, pcfg)
    assert sg.pcfg.indegree_cutoff is not None
    want = max(int(np.ceil(g.in_degrees().max() / 4)), 1)
    assert sg.pcfg.indegree_cutoff == want
    # pinned config reproduces the unpinned initial partition exactly
    _assert_parts_equal(sg.view("base").part, build_partition(g, pcfg))


# --------------------------------------------------------------------------
# serving integration: mutations between ticks (tentpole wiring)
# --------------------------------------------------------------------------

def test_server_mutation_between_ticks():
    from repro.query.server import QueryServer

    g = generators.rmat(6, edge_factor=6, seed=3).with_random_weights(seed=3)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=3,
                           local_edge_list_size=8, seed=9)
    sg = StreamingGraph(g, pcfg)
    srv = QueryServer(sg.view("base").part, n_lanes=4)
    sg.bind_server(srv)
    root = int(g.src[0])

    q1 = srv.submit("sssp", [root])
    srv.run()
    want = reference.sssp_dijkstra(g, root)
    fin = np.isfinite(want)
    np.testing.assert_allclose(srv.results[q1].values[fin], want[fin],
                               rtol=1e-6)

    rng = np.random.default_rng(5)
    s = rng.integers(0, g.n, 6).astype(np.int32)
    d = rng.integers(0, g.n, 6).astype(np.int32)
    sg.insert_edges(s, d, rng.integers(1, 10, 6).astype(np.float32))
    sg.commit()
    assert srv.counters["mutations"] == 1

    q2 = srv.submit("sssp", [root])
    srv.run()
    want = reference.sssp_dijkstra(sg.g, root)
    fin = np.isfinite(want)
    np.testing.assert_allclose(srv.results[q2].values[fin], want[fin],
                               rtol=1e-6)


def test_server_midflight_insert_warm_continues():
    from repro.query.server import QueryServer

    g = generators.rmat(7, edge_factor=6, seed=8)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=3,
                           local_edge_list_size=8, seed=4)
    sg = StreamingGraph(g, pcfg)
    srv = QueryServer(sg.view("base").part, n_lanes=2)
    sg.bind_server(srv)
    root = int(np.argmax(g.out_degrees()))
    q = srv.submit("bfs", [root])
    srv.step()                      # in flight
    rng = np.random.default_rng(3)
    s = rng.integers(0, g.n, 5).astype(np.int32)
    d = rng.integers(0, g.n, 5).astype(np.int32)
    sg.insert_edges(s, d)
    sg.commit()                     # insert-only: lane state migrates
    srv.run()
    np.testing.assert_array_equal(
        srv.results[q].values.astype(np.int64),
        reference.bfs_levels(sg.g, root))


def test_server_midflight_delete_restarts_lane():
    from repro.query.server import QueryServer

    g = generators.rmat(7, edge_factor=6, seed=8).with_random_weights(seed=8)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=3,
                           local_edge_list_size=8, seed=4)
    sg = StreamingGraph(g, pcfg)
    srv = QueryServer(sg.view("base").part, n_lanes=2)
    sg.bind_server(srv)
    root = int(np.argmax(g.out_degrees()))
    q = srv.submit("sssp", [root])
    srv.step()
    rng = np.random.default_rng(9)
    idx = rng.choice(sg.g.num_edges, 6, replace=False)
    sg.delete_edges(sg.g.src[idx], sg.g.dst[idx])
    sg.commit()                     # deletes: lane restarts cold
    srv.run()
    want = reference.sssp_dijkstra(sg.g, root)
    fin = np.isfinite(want)
    np.testing.assert_allclose(srv.results[q].values[fin], want[fin],
                               rtol=1e-6)


def test_server_cache_invalidation_modes():
    from repro.query.server import QueryServer
    from repro.serve.admission import ServeConfig

    g = generators.rmat(6, edge_factor=6, seed=3)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=2,
                           local_edge_list_size=8, seed=9)
    sg = StreamingGraph(g, pcfg)
    srv = QueryServer(sg.view("base").part, n_lanes=2,
                      serve=ServeConfig(cache_size=16))
    sg.bind_server(srv, cache_invalidation="all")
    root = int(g.src[0])
    q1 = srv.submit("bfs", [root])
    srv.run()
    q2 = srv.submit("bfs", [root])
    srv.run()
    assert srv.counters["cache_hits"] >= 1
    hits_before = srv.counters["cache_hits"]
    sg.insert_edges([int(g.dst[0])], [root])
    sg.commit()
    assert srv.counters["cache_invalidations"] >= 1
    q3 = srv.submit("bfs", [root])       # must recompute, not hit
    srv.run()
    assert srv.counters["cache_hits"] == hits_before
    np.testing.assert_array_equal(
        srv.results[q3].values.astype(np.int64),
        reference.bfs_levels(sg.g, root))


# --------------------------------------------------------------------------
# flight-recorder wiring
# --------------------------------------------------------------------------

def test_commit_records_mutation_span_and_gauges():
    from repro import obs

    g = generators.rmat(6, edge_factor=5, seed=2)
    pcfg = PartitionConfig(num_shards=4, rpvo_max=2,
                           local_edge_list_size=8, seed=3)
    sg = StreamingGraph(g, pcfg)
    sg.track("bfs", int(g.src[0]))
    with obs.recording() as rec:
        sg.insert_edges([1, 2], [3, 4])
        sg.commit()
    events = rec.tracer._events
    spans = [e for e in events if e["name"] == "mutation"]
    assert len(spans) == 1
    assert spans[0]["args"]["inserts"] == 2
    text = rec.registry.render_prometheus()
    assert 'stream_mutations_total{kind="insert"} 2' in text
    assert "stream_shards_rebuilt" in text
    assert "stream_affected_vertices" in text
