"""Delta-PageRank tests (ISSUE 5 tentpole: diffusion-pruned sum semiring).

Push-based residual propagation must converge to the numpy PageRank
reference on every execution path (stacked / sharded / laned-PPR, jnp /
fused / worklist / compact), and must do strictly less work than the
dense power iteration — fewer messages AND fewer live grid cells — the
first time the frontier machinery fires for the sum semiring.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.apps.pagerank import _pr_graph, pagerank, pagerank_delta
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.kernels.fused_relax_reduce import fused_grid_cells
from repro.query.lanes import run_ppr_delta_lanes


@pytest.fixture(scope="module")
def rmat_graph():
    return generators.rmat(8, edge_factor=6, seed=3)


@pytest.fixture(scope="module")
def rmat_reference(rmat_graph):
    return reference.pagerank(rmat_graph, iters=200)


CONFIGS = [
    ("jnp", engine.EngineConfig()),
    ("fused", engine.EngineConfig(use_pallas=True)),
    ("fused-worklist", engine.EngineConfig(use_pallas=True,
                                           grid_mode="worklist")),
    ("fused-auto", engine.EngineConfig(use_pallas=True, grid_mode="auto")),
    ("compact", engine.EngineConfig(exchange="compact")),
    ("compact-fused-wl", engine.EngineConfig(
        exchange="compact", use_pallas=True, grid_mode="worklist")),
    ("fused-wl-tiled", engine.EngineConfig(
        use_pallas=True, grid_mode="worklist", vmem_budget_bytes=256)),
]


@pytest.mark.parametrize("label,cfg", CONFIGS)
def test_delta_converges_to_reference(rmat_graph, rmat_reference, label,
                                      cfg):
    scores, stats, _ = pagerank_delta(rmat_graph, tol=1e-9, num_shards=8,
                                      rpvo_max=4, cfg=cfg, max_rounds=400)
    np.testing.assert_allclose(scores, rmat_reference, rtol=1e-4,
                               atol=1e-7)
    assert int(stats.iterations) > 0
    assert int(stats.messages) > 0
    assert int(stats.pruned_actions) > 0     # sub-tol residuals dropped


def test_delta_matches_dense_pagerank(rmat_graph):
    dense, _ = pagerank(rmat_graph, iters=100, num_shards=8, rpvo_max=4)
    delta, _, _ = pagerank_delta(rmat_graph, tol=1e-10, num_shards=8,
                                 rpvo_max=4, max_rounds=400)
    np.testing.assert_allclose(delta, dense, rtol=1e-4, atol=1e-8)


def test_delta_paths_agree_exactly_on_stats(rmat_graph):
    """Every grid mode prunes identically: same rounds, messages, work —
    the launch shape is an optimization, never a semantics change."""
    ref_stats = None
    for label, cfg in CONFIGS:
        _, stats, _ = pagerank_delta(rmat_graph, tol=1e-9, num_shards=8,
                                     rpvo_max=4, cfg=cfg, max_rounds=400)
        row = (int(stats.iterations), int(stats.messages),
               int(stats.work_actions), int(stats.pruned_actions))
        if ref_stats is None:
            ref_stats = row
        assert row == ref_stats, (label, row, ref_stats)


def test_delta_sharded_matches_stacked(rmat_graph, rmat_reference):
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cfg = engine.EngineConfig(use_pallas=True)
    st_scores, st_stats, part = pagerank_delta(
        rmat_graph, tol=1e-9, num_shards=1, cfg=cfg, max_rounds=400)
    sh_scores, sh_stats, _ = pagerank_delta(
        rmat_graph, tol=1e-9, num_shards=1, part=part, mesh=mesh, cfg=cfg,
        max_rounds=400)
    np.testing.assert_allclose(sh_scores, st_scores, rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(sh_scores, rmat_reference, rtol=1e-4,
                               atol=1e-7)
    assert int(sh_stats.iterations) == int(st_stats.iterations)
    assert int(sh_stats.messages) == int(st_stats.messages)


def test_delta_per_vertex_tolerance(rmat_graph, rmat_reference):
    """A per-vertex tol array is honored: uniform array == scalar, and a
    cranked-up tolerance on half the graph prunes more (fewer messages)
    while still bounding those vertices' error by the larger tol."""
    n = rmat_graph.n
    sc_scalar, st_scalar, part = pagerank_delta(
        rmat_graph, tol=1e-7, num_shards=8, rpvo_max=4, max_rounds=400)
    sc_arr, st_arr, _ = pagerank_delta(
        rmat_graph, tol=np.full(n, 1e-7, np.float32), part=part,
        max_rounds=400)
    np.testing.assert_array_equal(sc_arr, sc_scalar)
    assert int(st_arr.messages) == int(st_scalar.messages)
    mixed = np.full(n, 1e-7, np.float32)
    mixed[n // 2:] = 1e-3
    sc_mix, st_mix, _ = pagerank_delta(rmat_graph, tol=mixed, part=part,
                                       max_rounds=400)
    assert int(st_mix.messages) < int(st_scalar.messages)
    np.testing.assert_allclose(sc_mix, rmat_reference, atol=2e-2)


def test_delta_prunes_messages_and_cells_vs_dense(rmat_graph):
    """The ISSUE-5 acceptance bar: on the RMAT graph, delta-PageRank
    executes strictly fewer messages AND strictly fewer live grid cells
    than the same number of dense PageRank rounds — the frontier
    machinery finally bites for the sum semiring."""
    part = build_partition(_pr_graph(rmat_graph),
                           PartitionConfig(num_shards=8, rpvo_max=4))
    arrays = engine.DeviceArrays.from_partition(part)
    sem = actions.PAGERANK
    cfg = engine.EngineConfig(use_pallas=True)
    total = part.S * part.R_max
    damping, rounds_n = 0.85, 18

    # dense rounds: frontier is every valid slot, every round
    full = np.asarray(arrays.slot_valid).reshape(-1)
    dense_cells_round = fused_grid_cells(
        part.edge_dst_flat, part.edge_mask, part.edge_src_root_flat,
        full, total)["fused_live"]
    base = (1.0 - damping) / part.n
    val = jnp.where(arrays.slot_valid, 1.0 / part.n, 0.0)
    dense_msgs = 0
    for _ in range(rounds_n):
        val, mc = engine._pagerank_round_stacked(
            sem, arrays, cfg, part.S, part.R_max, base, damping, val,
            jnp.asarray(arrays.slot_valid))
        dense_msgs += int(mc)
    dense_cells = dense_cells_round * rounds_n

    # delta rounds: residual frontier shrinks (tol picked so the RMAT
    # residuals decay through it within the round budget — ~0.85^k decay
    # from base=(1-d)/n)
    tol = jnp.asarray(1e-5, jnp.float32)
    rank = delta = jnp.where(arrays.slot_valid, base, 0.0)
    delta_msgs = delta_cells = it = 0
    while it < rounds_n:
        chg_h = np.asarray((delta > tol) & arrays.slot_valid)
        if not chg_h.any():
            break
        delta_cells += fused_grid_cells(
            part.edge_dst_flat, part.edge_mask, part.edge_src_root_flat,
            chg_h.reshape(-1), total)["fused_live"]
        rank, delta, _, mc = engine.exchange.delta_pagerank_round_stacked(
            sem, arrays, cfg, part.S, part.R_max, damping, tol, rank,
            delta)
        delta_msgs += int(mc)
        it += 1
    assert delta_msgs < dense_msgs, (delta_msgs, dense_msgs)
    assert delta_cells < dense_cells, (delta_cells, dense_cells)


def test_delta_max_rounds_cap(rmat_graph):
    _, stats, _ = pagerank_delta(rmat_graph, tol=1e-12, num_shards=8,
                                 rpvo_max=4, max_rounds=3)
    assert int(stats.iterations) == 3


def test_ppr_delta_lanes_match_reference():
    g = generators.ba_skewed(200, m_per=3, seed=4)
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=4, rpvo_max=4))
    seeds = [7, 23, 101]
    dampings = [0.85, 0.9, 0.85]
    for cfg in (engine.EngineConfig(),
                engine.EngineConfig(use_pallas=True,
                                    grid_mode="worklist"),
                engine.EngineConfig(exchange="compact", use_pallas=True)):
        scores, stats = run_ppr_delta_lanes(
            part, seeds, dampings, cfg=cfg, tol=1e-10, max_rounds=500)
        vv = np.asarray(scores).reshape(-1, len(seeds))
        for i, (s, d) in enumerate(zip(seeds, dampings)):
            ref = reference.personalized_pagerank(g, s, d, tol=1e-12)
            got = vv[:, i][part.root_flat]
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-7)
            assert int(stats.rounds[i]) > 0


def test_ppr_delta_lanes_prune_vs_full_rounds():
    from repro.query.lanes import run_ppr_lanes
    g = generators.rmat(8, edge_factor=6, seed=3)
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=4, rpvo_max=4))
    seeds = [3, 50]
    cfg = engine.EngineConfig(use_pallas=True)
    _, st_full = run_ppr_lanes(part, seeds, 0.85, cfg=cfg, tol=1e-8,
                               max_rounds=200)
    _, st_delta = run_ppr_delta_lanes(part, seeds, 0.85, cfg=cfg,
                                      tol=1e-8, max_rounds=200)
    assert (np.asarray(st_delta.messages)
            < np.asarray(st_full.messages)).all()
